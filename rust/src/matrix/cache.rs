//! Matrix cache + async partition read-ahead and write-back (§III-B3).
//!
//! SAFS deliberately bypasses the OS page cache (a streaming scan would
//! only evict useful pages), so FlashMatrix supplies its **own** memory
//! hierarchy for external-memory matrices: a bounded, write-through cache
//! of I/O-level partitions keyed by `(matrix id, partition index)`.
//!
//! * **Write-through** — partitions written through a
//!   [`DenseBuilder`](crate::matrix::DenseBuilder) land on the SSD file
//!   *and* in the cache, so the file is always authoritative: eviction
//!   never loses data and a cache-bypassing read
//!   (e.g. [`crate::storage::StreamReader`]) is always consistent.
//! * **LRU with pinning** — capacity eviction removes the
//!   least-recently-used *unpinned* entry; pinned entries are skipped.
//!   Prefetched partitions carry one pin until their first consumer
//!   arrives, so read-ahead cannot be undone by eviction pressure.
//! * **Async read-ahead** — a dedicated prefetch thread pulls the next
//!   partition of a sequential scan into the cache while the current one
//!   is being computed ([`PartitionCache::prefetch`]), so single-worker
//!   EM passes overlap I/O with compute instead of alternating.
//!
//! * **Single-flight reads** — an in-flight read registry keyed like the
//!   cache. A demand read and a prefetch of the same partition (or two
//!   demand reads from racing workers) coalesce: one *leader* reads the
//!   file, every *follower* blocks until the leader's bytes land and then
//!   serves itself from the cache. This is what makes multi-worker
//!   read-ahead safe — for any partition the cache can admit, a prefetch
//!   can never cause a double read ([`PartitionCache::get_or_read`]).
//! * **Async write-back** — the write-side mirror of the prefetch
//!   thread: a pass worker hands a finished target partition to the
//!   background writer ([`PartitionCache::enqueue_write`]) and claims
//!   its next unit immediately, so the (throttled) `pwrite` overlaps the
//!   next partition's read and compute instead of stalling the worker.
//!   Dirty bytes are bounded (`writeback_queue_bytes`; a full queue
//!   blocks the enqueuer — [`crate::metrics::Metrics::wb_flush_waits`]),
//!   a re-write of a still-queued partition coalesces into one file
//!   write, and every pass ends with a **flush barrier** on success
//!   ([`PartitionCache::flush_writes`]) or a **dirty discard** on abort
//!   ([`PartitionCache::discard_writes`]) — so a finished matrix's file
//!   is authoritative before anyone can read it (results bit-identical
//!   to synchronous write-through) and a doomed pass leaves no partial
//!   partitions on disk. The invariant the exec layer maintains: no
//!   reader holds a finished matrix before its creating pass's flush
//!   barrier completed.
//!
//! With several engine **sessions** sharing one cache (multi-tenant
//! serving), eviction is fair-share: each registered tenant owns its
//! matrices' partitions ([`PartitionCache::set_matrix_owner`]) and a
//! tenant within its byte share is shielded from another tenant's
//! eviction pressure (cross-tenant evictions are charged to the victim's
//! own [`Metrics`]). Read-ahead requests are keyed by **pass id**
//! ([`PartitionCache::begin_pass`]), never a cache-global generation, so
//! one pass ending cannot retire a concurrent pass's prefetches; the
//! write-back dirty bound is split per tenant the same way; and
//! [`PartitionCache::set_max_concurrent_passes`] gates how many passes
//! may execute at once.
//!
//! Capacity comes from [`crate::config::EngineConfig::em_cache_bytes`]
//! (0 disables the cache — the Fig 11-style ablation knob, exercised by
//! `benches/cache_ablation.rs`); the read-ahead queue depth from
//! [`crate::config::EngineConfig::prefetch_depth`]. Hit / miss / eviction
//! / prefetch / coalesced-read counts are recorded in
//! [`crate::metrics::Metrics`].
//!
//! Cache *residency* is a materialization-time decision made by the `fmr`
//! layer: engine inputs and user-materialized results register with the
//! cache, while eager-mode one-shot intermediates bypass it entirely
//! (they would only evict reusable partitions; see
//! [`crate::fmr::engine::Engine::materialize_intermediate`]).

use std::collections::{HashMap, HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::{Arc, Condvar, Mutex};

use crate::error::{FmError, Result};
use crate::metrics::Metrics;
use crate::storage::FileStore;
use crate::util::sync::{wait_recover, LockExt};

/// One cached I/O-level partition.
struct Entry {
    bytes: Arc<Vec<u8>>,
    /// LRU clock value of the last touch.
    stamp: u64,
    /// Pin count; entries with `pins > 0` are never capacity-evicted.
    pins: u32,
    /// Prefetched entries carry one pin that clears on first hit.
    unpin_on_hit: bool,
}

/// One registered tenant of a shared cache (an engine session).
struct SessionSlot {
    /// Fair-share residency budget in bytes; 0 = dynamic (an equal split
    /// of the cache capacity across registered tenants).
    share: usize,
    /// The tenant's own metrics: hits/misses/cross-evictions of its
    /// matrices land here, not on the cache-owning engine's counters.
    metrics: Arc<Metrics>,
    /// Resident bytes currently owned by this tenant.
    resident: usize,
}

struct Inner {
    map: HashMap<(u64, usize), Entry>,
    bytes_used: usize,
    clock: u64,
    /// Matrix ids with a live [`CacheHandle`]. Prefetch completions for
    /// ids no longer here are dropped — otherwise a read-ahead finishing
    /// after its matrix was dropped would admit a pinned entry nothing
    /// can ever consume or evict.
    live: std::collections::HashSet<u64>,
    /// Passes currently executing (between [`PartitionCache::begin_pass`]
    /// and the [`PassGuard`] drop). A prefetch completion whose issuing
    /// pass is no longer here is stale — admitted unpinned.
    active_passes: HashSet<u64>,
    /// Matrix id -> owning session. Absent = the root tenant (id 0).
    owner: HashMap<u64, u64>,
    /// Registered tenants sharing this cache, by session id.
    sessions: HashMap<u64, SessionSlot>,
}

impl Inner {
    fn session_of(&self, matrix_id: u64) -> u64 {
        self.owner.get(&matrix_id).copied().unwrap_or(0)
    }

    fn add_resident(&mut self, matrix_id: u64, len: usize) {
        let s = self.session_of(matrix_id);
        if let Some(slot) = self.sessions.get_mut(&s) {
            slot.resident += len;
        }
    }

    fn sub_resident(&mut self, matrix_id: u64, len: usize) {
        let s = self.session_of(matrix_id);
        if let Some(slot) = self.sessions.get_mut(&s) {
            slot.resident = slot.resident.saturating_sub(len);
        }
    }

    fn resident_of(&self, session: u64) -> usize {
        self.sessions.get(&session).map_or(0, |s| s.resident)
    }

    /// A tenant's fair-share budget: its configured share, or an equal
    /// split of capacity when unset. Unregistered tenants get 0, so
    /// their entries are always preferred victims under contention.
    fn share_of(&self, session: u64, capacity: usize) -> usize {
        match self.sessions.get(&session) {
            Some(slot) if slot.share > 0 => slot.share,
            Some(_) => capacity / self.sessions.len().max(1),
            None => 0,
        }
    }
}

/// An asynchronous read request executed by the prefetch thread.
struct PrefetchReq {
    cache: Arc<PartitionCache>,
    store: Arc<FileStore>,
    matrix_id: u64,
    part: usize,
    off: u64,
    len: usize,
    /// Id of the pass that issued the read-ahead; a request whose pass
    /// has ended is stale — dropped before the read, or admitted
    /// unpinned after it. Keyed per pass (not cache-global) so one pass
    /// ending cannot retire a concurrent pass's read-aheads.
    pass: u64,
}

/// One queued asynchronous partition write. Holding the `Arc<FileStore>`
/// keeps the backing file alive (and un-unlinked) until the write lands
/// or the entry is discarded, even if the builder is dropped first.
struct WbEntry {
    store: Arc<FileStore>,
    off: u64,
    bytes: Arc<Vec<u8>>,
    /// Tenant that enqueued the write (for the per-tenant dirty budget).
    session: u64,
}

/// Dirty-partition state shared between enqueuers, the flush/discard
/// barriers and the background writer thread.
struct WbState {
    /// Write order (FIFO — the sequential pattern the SSD layer likes).
    /// Invariant: every key here has exactly one entry in `pending`.
    queue: VecDeque<(u64, usize)>,
    /// Queued writes by key; a re-enqueue of a queued key replaces the
    /// bytes in place (coalescing) instead of writing the file twice.
    pending: HashMap<(u64, usize), WbEntry>,
    /// Bytes held by queued + in-flight entries (the bounded dirty set).
    bytes: usize,
    /// Dirty bytes per tenant: with >= 2 registered sessions each tenant
    /// is bounded to its split of `capacity`, so one tenant's write
    /// burst cannot monopolize the shared queue (admission control).
    session_bytes: HashMap<u64, usize>,
    /// Key the writer thread is writing right now, if any.
    inflight: Option<(u64, usize)>,
    /// First write error per matrix id since that matrix's last flush.
    /// Keyed so one pass's failure can never surface through another
    /// pass's flush barrier (or survive its own discard).
    errs: HashMap<u64, FmError>,
    shutdown: bool,
}

/// The write-back pipeline: bounded dirty set + background writer. Held
/// by the cache behind an `Arc` the writer thread shares (no cycle: the
/// thread never holds the cache itself).
struct WriteBack {
    state: Mutex<WbState>,
    /// Writer wake-ups (new work, shutdown).
    work_cv: Condvar,
    /// Waiter wake-ups (capacity freed, a write finished).
    done_cv: Condvar,
    /// Dirty-capacity bound in bytes (`writeback_queue_bytes`).
    capacity: usize,
}

impl WriteBack {
    /// The writer thread: drain the queue FIFO, one (throttled) positioned
    /// write at a time, waking flush/capacity waiters after each. On
    /// shutdown the remaining queue is drained first so an engine dropped
    /// with clean-pass writes still pending loses nothing.
    fn writer_loop(wb: Arc<WriteBack>) {
        loop {
            let (key, entry) = {
                let mut st = wb.state.lock_recover();
                loop {
                    if let Some(key) = st.queue.pop_front() {
                        // a queued key always has bytes in `pending`; if
                        // the invariant was broken (state poisoned mid-
                        // update by a panicking peer), skip the key
                        // rather than killing the writer — a dead writer
                        // deadlocks every flush barrier
                        let Some(entry) = st.pending.remove(&key) else {
                            continue;
                        };
                        st.inflight = Some(key);
                        break (key, entry);
                    }
                    if st.shutdown {
                        return;
                    }
                    st = wait_recover(&wb.work_cv, st);
                }
            };
            // a panic inside the (throttled, fault-injected) write must
            // not take the writer thread down — it is surfaced like any
            // other write error through the matrix's flush barrier
            let res = catch_unwind(AssertUnwindSafe(|| {
                entry.store.write_at(entry.off, &entry.bytes)
            }))
            .unwrap_or_else(|_| {
                Err(FmError::Runtime(
                    "write-back writer panicked mid-write".into(),
                ))
            });
            let len = entry.bytes.len();
            let session = entry.session;
            // release the entry (and its FileStore Arc) BEFORE waking the
            // barriers: when a flush/discard observes inflight == None,
            // the writer must hold no reference to the matrix's backing
            // file — an aborted pass unlinks it right after
            drop(entry);
            let mut st = wb.state.lock_recover();
            st.inflight = None;
            st.bytes -= len;
            if let Some(b) = st.session_bytes.get_mut(&session) {
                *b = b.saturating_sub(len);
            }
            if let Err(e) = res {
                st.errs.entry(key.0).or_insert(e);
            }
            drop(st);
            wb.done_cv.notify_all();
        }
    }
}

/// Bounded write-through cache of I/O-level partitions (§III-B3).
///
/// Shared by every external-memory matrix of one engine; each matrix owns
/// a key namespace through its [`CacheHandle`].
pub struct PartitionCache {
    inner: Mutex<Inner>,
    capacity: usize,
    metrics: Arc<Metrics>,
    next_matrix_id: AtomicU64,
    prefetch_tx: Option<SyncSender<PrefetchReq>>,
    /// Single-flight registry: partitions with a read in progress. A
    /// second reader of the same key waits on the condvar instead of
    /// issuing its own file read.
    inflight: Mutex<HashSet<(u64, usize)>>,
    inflight_cv: Condvar,
    /// Pass-id allocator for [`begin_pass`](Self::begin_pass); starts at
    /// 1 so 0 can mean "no pass" (a prefetch issued outside any pass is
    /// immediately stale and lands unpinned).
    next_pass_id: AtomicU64,
    /// Wakes passes blocked on the `max_passes` admission gate.
    pass_cv: Condvar,
    /// Cap on concurrently executing passes (0 = unlimited).
    max_passes: AtomicUsize,
    /// Session-id allocator; starts at 1 (0 = the root tenant).
    next_session_id: AtomicU64,
    /// Asynchronous write-back pipeline; `None` = synchronous
    /// write-through (the `writeback` knob off, or queue sized 0).
    wb: Option<Arc<WriteBack>>,
}

/// RAII registration in the single-flight registry: the leader's slot is
/// released (and waiters woken) even if the read errors or panics.
struct InflightGuard<'a> {
    cache: &'a PartitionCache,
    key: (u64, usize),
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.cache.inflight.lock_recover().remove(&self.key);
        self.cache.inflight_cv.notify_all();
    }
}

impl PartitionCache {
    /// A cache of `capacity` bytes. `prefetch_depth > 0` also starts the
    /// read-ahead thread with a request queue of that depth;
    /// `writeback_queue_bytes > 0` starts the write-back writer thread
    /// with that dirty-capacity bound (0 = synchronous write-through).
    pub fn new(
        capacity: usize,
        prefetch_depth: usize,
        writeback_queue_bytes: usize,
        metrics: Arc<Metrics>,
    ) -> Arc<PartitionCache> {
        let (tx, rx) = if prefetch_depth > 0 {
            let (tx, rx) = sync_channel::<PrefetchReq>(prefetch_depth);
            (Some(tx), Some(rx))
        } else {
            (None, None)
        };
        let wb = if writeback_queue_bytes > 0 {
            let wb = Arc::new(WriteBack {
                state: Mutex::new(WbState {
                    queue: VecDeque::new(),
                    pending: HashMap::new(),
                    bytes: 0,
                    session_bytes: HashMap::new(),
                    inflight: None,
                    errs: HashMap::new(),
                    shutdown: false,
                }),
                work_cv: Condvar::new(),
                done_cv: Condvar::new(),
                capacity: writeback_queue_bytes,
            });
            let thread_wb = Arc::clone(&wb);
            // no writer thread -> no write-back: enqueue_write returning
            // false makes every builder fall back to synchronous
            // write-through instead of queueing writes nothing drains
            // (a lost prefetch thread only costs read-ahead; a lost
            // writer would deadlock the flush barrier)
            std::thread::Builder::new()
                .name("fm-writeback".into())
                .spawn(move || WriteBack::writer_loop(thread_wb))
                .ok()
                .map(|_| wb)
        } else {
            None
        };
        let cache = Arc::new(PartitionCache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                bytes_used: 0,
                clock: 0,
                live: std::collections::HashSet::new(),
                active_passes: HashSet::new(),
                owner: HashMap::new(),
                sessions: HashMap::new(),
            }),
            capacity,
            metrics,
            next_matrix_id: AtomicU64::new(0),
            prefetch_tx: tx,
            inflight: Mutex::new(HashSet::new()),
            inflight_cv: Condvar::new(),
            next_pass_id: AtomicU64::new(1),
            pass_cv: Condvar::new(),
            max_passes: AtomicUsize::new(0),
            next_session_id: AtomicU64::new(1),
            wb,
        });
        if let Some(rx) = rx {
            // The thread owns only the receiver; queued requests hold the
            // Arc transiently, so dropping the last engine reference drops
            // the sender and the thread exits.
            let _ = std::thread::Builder::new()
                .name("fm-prefetch".into())
                .spawn(move || {
                    while let Ok(req) = rx.recv() {
                        // a panicking store read must not kill read-ahead
                        // for the engine's lifetime: contain it, drop the
                        // one request (the InflightGuard's Drop still
                        // releases the single-flight slot during unwind)
                        let _ = catch_unwind(AssertUnwindSafe(|| {
                            // stale request: the pass that issued it is over,
                            // nobody will consume (and unpin) the read-ahead
                            if !req.cache.pass_active(req.pass) {
                                return;
                            }
                            // the consumer may have read the partition while
                            // this request sat in the queue — don't pay a
                            // second (throttled) store read for it
                            if req.cache.contains(req.matrix_id, req.part) {
                                return;
                            }
                            // single-flight: a demand read of the same
                            // partition is already on the file — coalesce
                            let Some(guard) = req.cache.begin_read(req.matrix_id, req.part)
                            else {
                                req.cache
                                    .metrics
                                    .singleflight_coalesced
                                    .fetch_add(1, Ordering::Relaxed);
                                return;
                            };
                            // a demand read may have completed between the
                            // contains() check and winning the slot
                            if req.cache.contains(req.matrix_id, req.part) {
                                drop(guard);
                                return;
                            }
                            let mut buf = vec![0u8; req.len];
                            if req.store.read_at(req.off, &mut buf).is_ok() {
                                req.cache
                                    .insert_prefetched(req.matrix_id, req.part, buf, req.pass);
                            }
                            drop(guard);
                        }));
                    }
                });
        }
        cache
    }

    /// Register a read of `(matrix_id, part)` in the single-flight
    /// registry. `Some(guard)` makes the caller the leader (the guard
    /// releases the slot on drop); `None` means another read of the same
    /// partition is already in flight.
    fn begin_read(&self, matrix_id: u64, part: usize) -> Option<InflightGuard<'_>> {
        let key = (matrix_id, part);
        if self.inflight.lock_recover().insert(key) {
            Some(InflightGuard { cache: self, key })
        } else {
            None
        }
    }

    /// Block until no read of `(matrix_id, part)` is in flight.
    fn wait_read(&self, matrix_id: u64, part: usize) {
        let key = (matrix_id, part);
        let mut g = self.inflight.lock_recover();
        while g.contains(&key) {
            g = wait_recover(&self.inflight_cv, g);
        }
    }

    /// Single-flight read-through lookup: serve `(matrix_id, part)` from
    /// the cache, or coalesce with an in-flight read of it, or execute
    /// `read` as the leader and admit the bytes. While the cache can
    /// admit the partition (it fits `capacity` and not everything else is
    /// pinned), at most one `read` runs per partition at any moment
    /// across demand readers *and* the prefetch thread — a pass never
    /// reads the same partition's bytes from the file twice. When the
    /// bytes *cannot* be admitted, a reader that already waited one full
    /// read out bypasses the registry and reads concurrently instead of
    /// serializing every reader behind file reads that keep evaporating.
    pub fn get_or_read(
        &self,
        matrix_id: u64,
        part: usize,
        read: impl FnOnce() -> Result<Vec<u8>>,
    ) -> Result<Arc<Vec<u8>>> {
        let mut read = Some(read);
        let mut waited = false;
        loop {
            // a follower already counted its miss on the first lookup:
            // peek (not get) after waiting, so one logical access is not
            // double-counted as a miss *and* a hit in the ablation numbers
            let found = if waited {
                self.peek(matrix_id, part)
            } else {
                self.get(matrix_id, part)
            };
            if let Some(b) = found {
                if waited {
                    // this read was served by someone else's file read
                    self.metrics
                        .singleflight_coalesced
                        .fetch_add(1, Ordering::Relaxed);
                }
                return Ok(b);
            }
            match self.begin_read(matrix_id, part) {
                Some(guard) => {
                    // leadership won — but a racing read may have completed
                    // and inserted between our miss-lookup and begin_read();
                    // re-check before paying a second file read
                    if let Some(b) = self.peek(matrix_id, part) {
                        drop(guard);
                        self.metrics
                            .singleflight_coalesced
                            .fetch_add(1, Ordering::Relaxed);
                        return Ok(b);
                    }
                    // leader: `read` is consumed at most once — a follower
                    // loops back here only after its leader failed, and
                    // then becomes the (sole) new leader
                    let bytes = Arc::new((read.take().expect("single-flight leader ran twice"))()?);
                    self.insert_shared(matrix_id, part, Arc::clone(&bytes));
                    drop(guard);
                    return Ok(bytes);
                }
                None => {
                    if waited {
                        // we already waited a full read out and the bytes
                        // still are not resident — the cache cannot admit
                        // this partition (smaller than one partition, or
                        // fully pinned). Stop serializing readers behind
                        // the registry: read concurrently, like an
                        // uncached matrix would.
                        return Ok(Arc::new(
                            (read.take().expect("bypass read ran twice"))()?,
                        ));
                    }
                    self.wait_read(matrix_id, part);
                    waited = true;
                }
            }
        }
    }

    /// Allocate a fresh matrix id (one key namespace per cached matrix)
    /// and mark it live for prefetch admission.
    pub fn alloc_matrix_id(&self) -> u64 {
        let id = self.next_matrix_id.fetch_add(1, Ordering::Relaxed);
        self.inner.lock_recover().live.insert(id);
        id
    }

    /// Configured capacity in bytes.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Bytes currently resident.
    pub fn bytes_used(&self) -> usize {
        self.inner.lock_recover().bytes_used
    }

    /// Bytes currently shielded from eviction by pins: the cross-pass
    /// optimizer's memoized intermediates (the [`crate::plan`] residency
    /// hint) plus transient read-ahead pins. Observability for tests and
    /// the figure harness.
    pub fn pinned_bytes(&self) -> usize {
        let g = self.inner.lock_recover();
        g.map
            .values()
            .filter(|e| e.pins > 0)
            .map(|e| e.bytes.len())
            .sum()
    }

    /// Number of resident partitions.
    pub fn len(&self) -> usize {
        self.inner.lock_recover().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether a partition is resident (no metric bump, no LRU touch).
    pub fn contains(&self, matrix_id: u64, part: usize) -> bool {
        self.inner
            .lock_recover()
            .map
            .contains_key(&(matrix_id, part))
    }

    /// Look up a partition. A hit refreshes LRU recency (and releases a
    /// prefetch pin); hits and misses are counted in [`Metrics`].
    pub fn get(&self, matrix_id: u64, part: usize) -> Option<Arc<Vec<u8>>> {
        self.lookup(matrix_id, part, true)
    }

    /// Like [`get`](Self::get) but without touching the hit/miss counters:
    /// for residency snapshots that are served another way on absence
    /// (e.g. the streaming export scan), where counting a "miss" would
    /// skew the ablation numbers. Still refreshes LRU recency and
    /// releases a prefetch pin on hit.
    pub fn peek(&self, matrix_id: u64, part: usize) -> Option<Arc<Vec<u8>>> {
        self.lookup(matrix_id, part, false)
    }

    fn lookup(&self, matrix_id: u64, part: usize, count: bool) -> Option<Arc<Vec<u8>>> {
        let mut g = self.inner.lock_recover();
        g.clock += 1;
        let clock = g.clock;
        let found = match g.map.get_mut(&(matrix_id, part)) {
            Some(e) => {
                e.stamp = clock;
                if e.unpin_on_hit {
                    e.unpin_on_hit = false;
                    e.pins = e.pins.saturating_sub(1);
                }
                Some(Arc::clone(&e.bytes))
            }
            None => None,
        };
        // hits/misses are attributed to the matrix's owning tenant so
        // per-session hit rates stay meaningful under interleaving (a
        // single-tenant engine registers its own metrics, so this is the
        // engine's counter as before); resolved under the lock, bumped
        // after dropping it
        let metrics = if count {
            Some(
                g.sessions
                    .get(&g.session_of(matrix_id))
                    .map(|slot| Arc::clone(&slot.metrics))
                    .unwrap_or_else(|| Arc::clone(&self.metrics)),
            )
        } else {
            None
        };
        drop(g);
        if let Some(m) = metrics {
            if found.is_some() {
                m.cache_hits.fetch_add(1, Ordering::Relaxed);
            } else {
                m.cache_misses.fetch_add(1, Ordering::Relaxed);
            }
        }
        found
    }

    /// Insert a partition (write-through population or post-miss fill).
    /// Replaces any previous bytes for the key; evicts LRU unpinned
    /// entries to make room. Entries larger than the whole cache are not
    /// admitted; if everything else is pinned the entry is dropped rather
    /// than blocking.
    pub fn insert(&self, matrix_id: u64, part: usize, bytes: Vec<u8>) {
        self.insert_entry(matrix_id, part, Arc::new(bytes), None);
    }

    /// [`insert`](Self::insert) for bytes already behind an `Arc` (the
    /// single-flight leader shares its buffer with the cache; a
    /// write-back builder shares one buffer between the dirty queue and
    /// the cache instead of copying twice).
    pub(crate) fn insert_shared(&self, matrix_id: u64, part: usize, bytes: Arc<Vec<u8>>) {
        self.insert_entry(matrix_id, part, bytes, None);
    }

    /// Prefetch insert: like [`insert`](Self::insert) but the entry holds
    /// one pin until its first hit, so eviction pressure cannot undo the
    /// read-ahead before its consumer arrives. If the consumer beat the
    /// prefetch the existing entry is kept untouched. `pass` is the id of
    /// the pass that issued the read-ahead: a completion from a pass that
    /// has since ended is admitted *unpinned* (the bytes are still
    /// useful, but no consumer remains to release a pin).
    fn insert_prefetched(&self, matrix_id: u64, part: usize, bytes: Vec<u8>, pass: u64) {
        self.insert_entry(matrix_id, part, Arc::new(bytes), Some(pass));
    }

    fn insert_entry(
        &self,
        matrix_id: u64,
        part: usize,
        bytes: Arc<Vec<u8>>,
        prefetched_pass: Option<u64>,
    ) {
        let len = bytes.len();
        if len > self.capacity {
            return;
        }
        let mut g = self.inner.lock_recover();
        let inner = &mut *g;
        inner.clock += 1;
        let stamp = inner.clock;
        // pass liveness checked under the inner lock: the pass-end sweep
        // (PassGuard drop, then release_prefetch_pins) also takes it, so
        // a late completion can never re-pin after the sweep — and only
        // the issuing pass's own end retires it, never a concurrent one
        let prefetched = match prefetched_pass {
            Some(p) => {
                if !inner.live.contains(&matrix_id) {
                    return; // matrix dropped while the read-ahead was in flight
                }
                inner.active_passes.contains(&p)
            }
            None => false,
        };
        if let Some(e) = inner.map.get_mut(&(matrix_id, part)) {
            if prefetched {
                return; // consumer's copy is already there; keep it
            }
            // a direct insert means the consumer has come and gone; a
            // still-pending read-ahead pin has served its purpose — keep
            // it and the entry would be pinned forever
            if e.unpin_on_hit {
                e.unpin_on_hit = false;
                e.pins = e.pins.saturating_sub(1);
            }
            let old = e.bytes.len();
            e.bytes = bytes;
            e.stamp = stamp;
            inner.bytes_used = inner.bytes_used - old + len;
            inner.sub_resident(matrix_id, old);
            inner.add_resident(matrix_id, len);
            return;
        }
        // fair-share victim selection only kicks in with >= 2 registered
        // tenants; a single-engine cache keeps plain global LRU
        let fair = inner.sessions.len() >= 2;
        let inserter = inner.session_of(matrix_id);
        let mut evicted = 0u64;
        let mut cross_victims: Vec<u64> = Vec::new();
        while inner.bytes_used + len > self.capacity {
            let global_lru = |inner: &Inner| {
                inner
                    .map
                    .iter()
                    .filter(|(_, e)| e.pins == 0)
                    .min_by_key(|(_, e)| e.stamp)
                    .map(|(k, _)| *k)
            };
            let victim = if fair {
                // prefer victims the inserting tenant is entitled to
                // displace — its own entries, or a tenant over its byte
                // share — so one tenant's streaming scan cannot flush
                // another tenant's in-budget working set. If every
                // tenant is within budget, fall back to global LRU so
                // admission never fails while unpinned bytes exist.
                inner
                    .map
                    .iter()
                    .filter(|(_, e)| e.pins == 0)
                    .filter(|(k, _)| {
                        let vs = inner.session_of(k.0);
                        vs == inserter
                            || inner.resident_of(vs) > inner.share_of(vs, self.capacity)
                    })
                    .min_by_key(|(_, e)| e.stamp)
                    .map(|(k, _)| *k)
                    .or_else(|| global_lru(inner))
            } else {
                global_lru(inner)
            };
            match victim {
                Some(k) => {
                    if let Some(e) = inner.map.remove(&k) {
                        let vlen = e.bytes.len();
                        inner.bytes_used -= vlen;
                        inner.sub_resident(k.0, vlen);
                        let vs = inner.session_of(k.0);
                        if fair && vs != inserter {
                            cross_victims.push(vs);
                        }
                    }
                    evicted += 1;
                }
                None => {
                    // everything resident is pinned: skip admission
                    if evicted > 0 {
                        self.metrics
                            .cache_evictions
                            .fetch_add(evicted, Ordering::Relaxed);
                    }
                    return;
                }
            }
        }
        inner.bytes_used += len;
        inner.add_resident(matrix_id, len);
        inner.map.insert(
            (matrix_id, part),
            Entry {
                bytes,
                stamp,
                pins: u32::from(prefetched),
                unpin_on_hit: prefetched,
            },
        );
        // cross-tenant evictions are charged to the *victim's* metrics —
        // that is the tenant whose working set shrank (isolation signal)
        let cross_metrics: Vec<Arc<Metrics>> = cross_victims
            .iter()
            .map(|s| {
                inner
                    .sessions
                    .get(s)
                    .map(|slot| Arc::clone(&slot.metrics))
                    .unwrap_or_else(|| Arc::clone(&self.metrics))
            })
            .collect();
        drop(g);
        if evicted > 0 {
            self.metrics
                .cache_evictions
                .fetch_add(evicted, Ordering::Relaxed);
        }
        for m in cross_metrics {
            m.cache_cross_evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Pin a resident partition: LRU eviction will skip it until every
    /// pin is released. Returns `false` when the partition is not
    /// resident (nothing to pin).
    pub fn pin(&self, matrix_id: u64, part: usize) -> bool {
        let mut g = self.inner.lock_recover();
        match g.map.get_mut(&(matrix_id, part)) {
            Some(e) => {
                e.pins += 1;
                true
            }
            None => false,
        }
    }

    /// Release one pin of a resident partition.
    pub fn unpin(&self, matrix_id: u64, part: usize) {
        let mut g = self.inner.lock_recover();
        if let Some(e) = g.map.get_mut(&(matrix_id, part)) {
            e.pins = e.pins.saturating_sub(1);
            e.unpin_on_hit = false;
        }
    }

    /// Begin a pass: allocate the id that keys its read-ahead requests
    /// and, when [`set_max_concurrent_passes`] is set, wait for an
    /// execution slot (admission control for multi-tenant serving). The
    /// returned guard retires the pass on drop — success or abort — so
    /// its leftover prefetch requests are dropped at dequeue and
    /// in-flight ones land unpinned. Because retirement is keyed per
    /// pass id, one pass ending can never invalidate a concurrent
    /// pass's queued read-aheads or drop its prefetch pins (the old
    /// cache-global epoch did exactly that).
    ///
    /// [`set_max_concurrent_passes`]: Self::set_max_concurrent_passes
    pub fn begin_pass(self: &Arc<Self>) -> PassGuard {
        let id = self.next_pass_id.fetch_add(1, Ordering::Relaxed);
        let mut g = self.inner.lock_recover();
        loop {
            let max = self.max_passes.load(Ordering::Relaxed);
            if max == 0 || g.active_passes.len() < max {
                break;
            }
            g = wait_recover(&self.pass_cv, g);
        }
        g.active_passes.insert(id);
        drop(g);
        PassGuard {
            cache: Arc::clone(self),
            id,
        }
    }

    /// Whether a pass is still executing (its read-aheads may still pin).
    fn pass_active(&self, pass: u64) -> bool {
        self.inner.lock_recover().active_passes.contains(&pass)
    }

    fn end_pass(&self, id: u64) {
        self.inner.lock_recover().active_passes.remove(&id);
        self.pass_cv.notify_all();
    }

    /// Cap on concurrently executing passes (0 = unlimited):
    /// [`begin_pass`](Self::begin_pass) blocks past the cap. From
    /// [`crate::config::EngineConfig::max_concurrent_passes`].
    pub fn set_max_concurrent_passes(&self, max: usize) {
        self.max_passes.store(max, Ordering::Relaxed);
        self.pass_cv.notify_all();
    }

    // -- multi-tenant sessions ----------------------------------------------

    /// Register a tenant: cache hits/misses/cross-evictions of its
    /// matrices are attributed to `metrics`, and `share_bytes` (0 = an
    /// equal split of capacity) bounds how many resident bytes it may
    /// hold before its entries become preferred eviction victims.
    /// Fair-share victim selection activates only once >= 2 tenants are
    /// registered, so a single-engine cache behaves exactly as before.
    pub fn register_session(&self, metrics: Arc<Metrics>, share_bytes: usize) -> u64 {
        let id = self.next_session_id.fetch_add(1, Ordering::Relaxed);
        self.inner.lock_recover().sessions.insert(
            id,
            SessionSlot {
                share: share_bytes,
                metrics,
                resident: 0,
            },
        );
        id
    }

    /// Drop a tenant registration. Its matrices fall back to the root
    /// tenant (id 0): still resident, but preferred victims from now on.
    pub fn unregister_session(&self, session: u64) {
        let mut g = self.inner.lock_recover();
        g.sessions.remove(&session);
        g.owner.retain(|_, s| *s != session);
        drop(g);
        // one fewer tenant widens the per-tenant dirty split and may
        // relax the fair-share picture; wake anyone blocked on either
        if let Some(wb) = &self.wb {
            wb.done_cv.notify_all();
        }
        self.pass_cv.notify_all();
    }

    /// Attribute a matrix (its residency, hits/misses and dirty bytes)
    /// to a tenant. Already-resident bytes move between ledgers.
    pub fn set_matrix_owner(&self, matrix_id: u64, session: u64) {
        let mut g = self.inner.lock_recover();
        let bytes: usize = g
            .map
            .iter()
            .filter(|(k, _)| k.0 == matrix_id)
            .map(|(_, e)| e.bytes.len())
            .sum();
        if bytes > 0 {
            g.sub_resident(matrix_id, bytes);
        }
        if session == 0 {
            g.owner.remove(&matrix_id);
        } else {
            g.owner.insert(matrix_id, session);
        }
        if bytes > 0 {
            g.add_resident(matrix_id, bytes);
        }
    }

    /// Resident bytes currently owned by one tenant (observability for
    /// the fair-share tests and the multitenant bench).
    pub fn session_resident_bytes(&self, session: u64) -> usize {
        self.inner.lock_recover().resident_of(session)
    }

    /// Number of registered tenants.
    pub fn session_count(&self) -> usize {
        self.inner.lock_recover().sessions.len()
    }

    /// Release one matrix's outstanding read-ahead pins (entries
    /// prefetched but not yet consumed). An aborted pass may never send
    /// the consumer a prefetched partition was pinned for; without this
    /// sweep the pin would shield the entry from eviction for the
    /// matrix's lifetime and permanently shrink the cache. Scoping by
    /// matrix id limits the blast radius: a concurrent pass only loses
    /// pins when it scans one of the sweeping pass's own matrices — its
    /// queued read-aheads (keyed by its own pass id) and its demand
    /// reads stay correct either way.
    pub fn release_prefetch_pins(&self, matrix_id: u64) {
        let mut g = self.inner.lock_recover();
        for (k, e) in g.map.iter_mut() {
            if k.0 == matrix_id && e.unpin_on_hit {
                e.unpin_on_hit = false;
                e.pins = e.pins.saturating_sub(1);
            }
        }
    }

    /// Drop every resident partition while keeping matrix registrations
    /// (`live` ids) intact: benches and tests use this to force a cold
    /// scan without re-registering matrices. Pins are ignored and nothing
    /// is counted as a capacity eviction.
    pub fn clear(&self) {
        let mut g = self.inner.lock_recover();
        g.map.clear();
        g.bytes_used = 0;
        for slot in g.sessions.values_mut() {
            slot.resident = 0;
        }
    }

    /// Drop every partition of one matrix (its handle was dropped).
    /// Ignores pins — the owner is gone, nothing can consume them — and
    /// retires the id so late prefetch completions are not admitted.
    pub fn evict_matrix(&self, matrix_id: u64) {
        let mut g = self.inner.lock_recover();
        let inner = &mut *g;
        inner.live.remove(&matrix_id);
        let keys: Vec<(u64, usize)> = inner
            .map
            .keys()
            .filter(|k| k.0 == matrix_id)
            .copied()
            .collect();
        for k in keys {
            if let Some(e) = inner.map.remove(&k) {
                let len = e.bytes.len();
                inner.bytes_used -= len;
                inner.sub_resident(k.0, len);
            }
        }
        inner.owner.remove(&matrix_id);
    }

    /// Queue an asynchronous read of one partition into the cache. Best
    /// effort by design: the request is dropped when the partition is
    /// already resident, read-ahead is disabled, or the queue is full —
    /// compute never blocks on read-ahead.
    pub fn prefetch(
        cache: &Arc<PartitionCache>,
        store: &Arc<FileStore>,
        matrix_id: u64,
        part: usize,
        off: u64,
        len: usize,
        pass: u64,
    ) {
        let Some(tx) = &cache.prefetch_tx else { return };
        // a partition larger than the whole cache can never be admitted:
        // reading it ahead would only make its demand reader serialize
        // behind a futile read and then re-read the file
        if len > cache.capacity || cache.contains(matrix_id, part) {
            return;
        }
        let req = PrefetchReq {
            cache: Arc::clone(cache),
            store: Arc::clone(store),
            matrix_id,
            part,
            off,
            len,
            pass,
        };
        if tx.try_send(req).is_ok() {
            cache
                .metrics
                .prefetch_issued
                .fetch_add(1, Ordering::Relaxed);
        }
    }

    // -- asynchronous write-back (§III-B3, the write half) ------------------

    /// Whether this cache hosts a write-back writer thread.
    pub fn writeback_enabled(&self) -> bool {
        self.wb.is_some()
    }

    /// Allocate a key namespace for a write-back-only producer (a builder
    /// whose matrix is *not* cache-resident still needs unique dirty
    /// keys). Shares the counter with
    /// [`alloc_matrix_id`](Self::alloc_matrix_id) but does not register
    /// the id as live — no cache entries, no prefetch admission, nothing
    /// to clean up.
    pub fn alloc_wb_id(&self) -> u64 {
        self.next_matrix_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Queue an asynchronous write of one target partition: `bytes` land
    /// at `off` in `store` from the background writer thread. Returns
    /// `false` when write-back is disabled (the caller writes through
    /// synchronously instead).
    ///
    /// Blocks while the dirty set is at capacity
    /// (`Metrics::wb_flush_waits`) — back-pressure, mirroring the
    /// read-ahead queue's bound. With >= 2 registered tenants the bound
    /// is additionally split per tenant (admission control): one
    /// tenant's write burst blocks only itself, never the whole queue.
    /// A re-enqueue of a still-queued key replaces its bytes in place
    /// (`Metrics::wb_coalesced`): one file write, newest bytes. Ordering
    /// per key is preserved — a key whose write is already in flight is
    /// re-queued behind it, so the newest bytes always land last.
    pub fn enqueue_write(
        &self,
        store: &Arc<FileStore>,
        matrix_id: u64,
        part: usize,
        off: u64,
        bytes: Arc<Vec<u8>>,
    ) -> bool {
        let Some(wb) = &self.wb else { return false };
        let key = (matrix_id, part);
        let len = bytes.len();
        // resolve the writing tenant and its dirty budget first: inner
        // lock, then wb lock — the two are never held together
        let (session, session_cap) = {
            let g = self.inner.lock_recover();
            let n = g.sessions.len();
            let cap = if n >= 2 { wb.capacity / n } else { wb.capacity };
            (g.session_of(matrix_id), cap)
        };
        let mut g = wb.state.lock_recover();
        {
            let st = &mut *g;
            if let Some(e) = st.pending.get_mut(&key) {
                let old = e.bytes.len();
                st.bytes = st.bytes - old + len;
                if let Some(b) = st.session_bytes.get_mut(&e.session) {
                    *b = b.saturating_sub(old) + len;
                }
                e.off = off;
                e.bytes = bytes;
                self.metrics.wb_coalesced.fetch_add(1, Ordering::Relaxed);
                return true;
            }
        }
        // bounded dirty capacity: wait for the writer to drain. A single
        // entry larger than the whole bound is admitted alone (when the
        // queue is otherwise empty) rather than deadlocking; the same
        // exemption applies to the per-tenant split.
        let mut waited = false;
        loop {
            let sb = g.session_bytes.get(&session).copied().unwrap_or(0);
            let global_full = g.bytes > 0 && g.bytes + len > wb.capacity;
            let tenant_full = sb > 0 && sb + len > session_cap;
            if !global_full && !tenant_full {
                break;
            }
            if !waited {
                waited = true;
                self.metrics.wb_flush_waits.fetch_add(1, Ordering::Relaxed);
            }
            g = wait_recover(&wb.done_cv, g);
        }
        g.bytes += len;
        *g.session_bytes.entry(session).or_insert(0) += len;
        g.pending.insert(
            key,
            WbEntry {
                store: Arc::clone(store),
                off,
                bytes,
                session,
            },
        );
        g.queue.push_back(key);
        drop(g);
        self.metrics.wb_enqueued.fetch_add(1, Ordering::Relaxed);
        wb.work_cv.notify_one();
        true
    }

    /// Pass-end flush barrier for one matrix: block until none of its
    /// writes are queued or in flight, then surface the matrix's first
    /// write error recorded since its last flush (errors are keyed per
    /// matrix, so a concurrent pass's failure never surfaces here). The
    /// exec layer calls this on every successful pass's builders *before*
    /// freezing them, which is what keeps write-back results
    /// bit-identical to write-through — the file is authoritative again
    /// before any reader can exist.
    pub fn flush_writes(&self, matrix_id: u64) -> Result<()> {
        let Some(wb) = &self.wb else { return Ok(()) };
        let mut g = wb.state.lock_recover();
        let mut waited = false;
        while g.pending.keys().any(|k| k.0 == matrix_id)
            || g.inflight.map(|k| k.0 == matrix_id).unwrap_or(false)
        {
            if !waited {
                waited = true;
                self.metrics.wb_flush_waits.fetch_add(1, Ordering::Relaxed);
            }
            g = wait_recover(&wb.done_cv, g);
        }
        match g.errs.remove(&matrix_id) {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Abort-path discard for one matrix: drop its queued writes
    /// (`Metrics::wb_discarded`) and wait out an in-flight one, so when
    /// this returns the writer will never touch the matrix's file again
    /// — a doomed pass leaves no partial partitions behind and the
    /// builder's backing file can be unlinked safely. Scoped by matrix
    /// id: concurrent passes' writes are untouched.
    pub fn discard_writes(&self, matrix_id: u64) {
        let Some(wb) = &self.wb else { return };
        let mut g = wb.state.lock_recover();
        {
            let st = &mut *g;
            let before = st.queue.len();
            st.queue.retain(|k| k.0 != matrix_id);
            let dropped = before - st.queue.len();
            if dropped > 0 {
                let keys: Vec<(u64, usize)> = st
                    .pending
                    .keys()
                    .filter(|k| k.0 == matrix_id)
                    .copied()
                    .collect();
                for k in keys {
                    if let Some(e) = st.pending.remove(&k) {
                        let len = e.bytes.len();
                        st.bytes -= len;
                        if let Some(b) = st.session_bytes.get_mut(&e.session) {
                            *b = b.saturating_sub(len);
                        }
                    }
                }
                self.metrics
                    .wb_discarded
                    .fetch_add(dropped as u64, Ordering::Relaxed);
            }
        }
        // an in-flight write cannot be recalled mid-pwrite; wait it out
        // so the partition on disk is whole, never partial
        while g.inflight.map(|k| k.0 == matrix_id).unwrap_or(false) {
            g = wait_recover(&wb.done_cv, g);
        }
        // the discarded matrix's recorded write error dies with it (after
        // the inflight wait, so a just-failed write cannot re-insert it):
        // nothing will ever flush this id again
        g.errs.remove(&matrix_id);
        drop(g);
        // discarding freed dirty capacity: wake blocked enqueuers
        wb.done_cv.notify_all();
    }
}

impl Drop for PartitionCache {
    fn drop(&mut self) {
        // stop the write-back writer; it drains the remaining queue
        // first, so pending clean-pass writes still land
        if let Some(wb) = &self.wb {
            wb.state.lock_recover().shutdown = true;
            wb.work_cv.notify_all();
        }
    }
}

/// A matrix's registration in the engine cache: the shared cache plus the
/// matrix's private key namespace. Dropping the handle (it lives inside
/// the matrix backing) evicts all of the matrix's partitions.
pub struct CacheHandle {
    pub cache: Arc<PartitionCache>,
    pub matrix_id: u64,
}

impl CacheHandle {
    pub fn register(cache: Arc<PartitionCache>) -> CacheHandle {
        let matrix_id = cache.alloc_matrix_id();
        CacheHandle { cache, matrix_id }
    }
}

impl Drop for CacheHandle {
    fn drop(&mut self) {
        self.cache.evict_matrix(self.matrix_id);
    }
}

/// RAII registration of one executing pass, from
/// [`PartitionCache::begin_pass`]. [`id`](PassGuard::id) keys the pass's
/// read-ahead requests; dropping the guard retires exactly this pass's
/// prefetches (queued ones are dropped at dequeue, in-flight ones land
/// unpinned) and frees its `max_concurrent_passes` slot.
pub struct PassGuard {
    cache: Arc<PartitionCache>,
    id: u64,
}

impl PassGuard {
    /// The pass id to stamp on [`PartitionCache::prefetch`] requests.
    pub fn id(&self) -> u64 {
        self.id
    }
}

impl Drop for PassGuard {
    fn drop(&mut self) {
        self.cache.end_pass(self.id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::SsdSim;

    fn cache(cap: usize) -> Arc<PartitionCache> {
        PartitionCache::new(cap, 0, 0, Arc::new(Metrics::new()))
    }

    #[test]
    fn lru_evicts_oldest_untouched() {
        let c = cache(300);
        c.insert(0, 0, vec![0u8; 100]);
        c.insert(0, 1, vec![1u8; 100]);
        c.insert(0, 2, vec![2u8; 100]);
        assert_eq!(c.bytes_used(), 300);
        // touch partition 0 so partition 1 becomes the LRU victim
        assert!(c.get(0, 0).is_some());
        c.insert(0, 3, vec![3u8; 100]);
        assert!(c.contains(0, 0));
        assert!(!c.contains(0, 1), "LRU partition must be evicted");
        assert!(c.contains(0, 2) && c.contains(0, 3));
        assert_eq!(c.metrics.snapshot().cache_evictions, 1);
    }

    #[test]
    fn pinned_entries_survive_pressure() {
        let c = cache(200);
        c.insert(7, 0, vec![0u8; 100]);
        c.insert(7, 1, vec![0u8; 100]);
        assert!(c.pin(7, 0));
        c.insert(7, 2, vec![0u8; 100]); // must evict 1, not pinned 0
        assert!(c.contains(7, 0) && !c.contains(7, 1) && c.contains(7, 2));
        assert!(c.pin(7, 2));
        // everything pinned: new entries are skipped, not deadlocked
        c.insert(7, 3, vec![0u8; 100]);
        assert!(!c.contains(7, 3));
        // releasing a pin makes its entry evictable again
        c.unpin(7, 0);
        c.insert(7, 4, vec![0u8; 100]);
        assert!(!c.contains(7, 0) && c.contains(7, 2) && c.contains(7, 4));
    }

    #[test]
    fn oversized_skipped_and_replacement_accounted() {
        let c = cache(250);
        c.insert(1, 0, vec![0u8; 300]); // larger than the cache
        assert!(c.is_empty());
        c.insert(1, 1, vec![1u8; 100]);
        c.insert(1, 1, vec![2u8; 200]); // replacement re-accounts bytes
        assert_eq!(c.bytes_used(), 200);
        assert_eq!(c.get(1, 1).unwrap()[0], 2);
    }

    #[test]
    fn evict_matrix_is_scoped_to_one_id() {
        let c = cache(1000);
        c.insert(1, 0, vec![0u8; 100]);
        c.insert(2, 0, vec![0u8; 100]);
        c.evict_matrix(1);
        assert!(!c.contains(1, 0) && c.contains(2, 0));
        assert_eq!(c.bytes_used(), 100);
    }

    #[test]
    fn handle_drop_evicts_its_matrix() {
        let c = cache(1000);
        let h = CacheHandle::register(Arc::clone(&c));
        let other = CacheHandle::register(Arc::clone(&c));
        assert_ne!(h.matrix_id, other.matrix_id);
        c.insert(h.matrix_id, 0, vec![0u8; 64]);
        c.insert(other.matrix_id, 0, vec![0u8; 64]);
        drop(h);
        assert_eq!(c.len(), 1);
        assert!(c.contains(other.matrix_id, 0));
    }

    #[test]
    fn hit_miss_counters() {
        let c = cache(1000);
        c.insert(3, 0, vec![0u8; 10]);
        assert!(c.get(3, 0).is_some());
        assert!(c.get(3, 1).is_none());
        let s = c.metrics.snapshot();
        assert_eq!((s.cache_hits, s.cache_misses), (1, 1));
    }

    #[test]
    fn prefetch_lands_pinned_until_first_hit() {
        let dir = crate::testutil::TempDir::new("cache-pf");
        let metrics = Arc::new(Metrics::new());
        let c = PartitionCache::new(512, 2, 0, Arc::clone(&metrics));
        let ssd = Arc::new(SsdSim::new(None));
        let store =
            Arc::new(FileStore::create(dir.path(), None, 256, ssd, Arc::clone(&metrics)).unwrap());
        store.write_at(0, &[42u8; 256]).unwrap();

        // prefetch only lands for live (registered) matrix ids
        let h = CacheHandle::register(Arc::clone(&c));
        let id = h.matrix_id;
        let pass = c.begin_pass();
        PartitionCache::prefetch(&c, &store, id, 0, 0, 256, pass.id());
        for _ in 0..2000 {
            if c.contains(id, 0) {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(c.contains(id, 0), "prefetch did not land");
        assert_eq!(metrics.snapshot().prefetch_issued, 1);

        // pinned: pressure cannot evict it before its consumer arrives
        c.insert(id, 1, vec![0u8; 384]);
        assert!(c.contains(id, 0) && !c.contains(id, 1));

        // the first hit consumes the read-ahead and releases the pin
        assert_eq!(c.get(id, 0).unwrap()[0], 42);
        c.insert(id, 2, vec![0u8; 384]);
        assert!(!c.contains(id, 0) && c.contains(id, 2));
    }

    #[test]
    fn direct_insert_releases_stale_prefetch_pin() {
        // consumer missed, read the file itself, then its insert() lands
        // on top of a prefetched (pinned) entry: the stale read-ahead pin
        // must be released or the entry is pinned forever
        let c = cache(300);
        let h = CacheHandle::register(Arc::clone(&c));
        let id = h.matrix_id;
        let pass = c.begin_pass();
        c.insert_prefetched(id, 0, vec![1u8; 100], pass.id());
        c.insert(id, 0, vec![2u8; 100]); // consumer refill
        c.insert(id, 1, vec![0u8; 100]);
        c.insert(id, 2, vec![0u8; 100]);
        c.insert(id, 3, vec![0u8; 100]); // pressure: (id,0) must be evictable
        assert!(!c.contains(id, 0), "stale prefetch pin leaked");
        assert_eq!(c.get(id, 0), None);
        assert_eq!(c.get(id, 3).unwrap()[0], 0);
    }

    #[test]
    fn single_flight_coalesces_concurrent_reads() {
        let c = cache(10_000);
        let h = CacheHandle::register(Arc::clone(&c));
        let id = h.matrix_id;
        let reads = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = Arc::clone(&c);
                let reads = &reads;
                s.spawn(move || {
                    let b = c
                        .get_or_read(id, 0, || {
                            reads.fetch_add(1, Ordering::SeqCst);
                            std::thread::sleep(std::time::Duration::from_millis(20));
                            Ok(vec![9u8; 64])
                        })
                        .unwrap();
                    assert_eq!(b[0], 9);
                });
            }
        });
        assert_eq!(reads.load(Ordering::SeqCst), 1, "only the leader reads the file");
        // every non-leader was served without its own read: either it
        // coalesced onto the in-flight read or it arrived late and hit
        let m = c.metrics.snapshot();
        assert!(
            m.singleflight_coalesced + m.cache_hits >= 3,
            "followers must be served by the leader's bytes \
             (coalesced {}, hits {})",
            m.singleflight_coalesced,
            m.cache_hits
        );
    }

    #[test]
    fn single_flight_leader_failure_is_not_sticky() {
        let c = cache(1000);
        let h = CacheHandle::register(Arc::clone(&c));
        let id = h.matrix_id;
        let r = c.get_or_read(id, 0, || {
            Err(crate::error::FmError::Storage("boom".into()))
        });
        assert!(r.is_err());
        // the failed leader released its slot: a retry reads fresh
        let b = c.get_or_read(id, 0, || Ok(vec![1u8; 8])).unwrap();
        assert_eq!(b[0], 1);
        assert!(c.contains(id, 0));
    }

    #[test]
    fn release_prefetch_pins_makes_orphans_evictable() {
        let c = cache(200);
        let h1 = CacheHandle::register(Arc::clone(&c));
        let h2 = CacheHandle::register(Arc::clone(&c));
        let (id1, id2) = (h1.matrix_id, h2.matrix_id);
        let pass = c.begin_pass();
        c.insert_prefetched(id1, 0, vec![1u8; 100], pass.id());
        c.insert_prefetched(id2, 0, vec![1u8; 100], pass.id());
        // orphaned read-ahead pins block every admission
        c.insert(id1, 2, vec![0u8; 100]);
        assert!(!c.contains(id1, 2), "fully pinned cache must skip admission");
        // the abort-path sweep releases only the aborted pass's matrix
        c.release_prefetch_pins(id1);
        c.insert(id1, 3, vec![0u8; 100]);
        assert!(c.contains(id1, 3), "released entries must be evictable");
        assert!(!c.contains(id1, 0), "the released orphan is the victim");
        assert!(c.contains(id2, 0), "other matrices' read-aheads stay pinned");
        assert_eq!(c.bytes_used(), 200);
    }

    #[test]
    fn clear_empties_but_keeps_registrations() {
        let c = cache(1000);
        let h = CacheHandle::register(Arc::clone(&c));
        c.insert(h.matrix_id, 0, vec![0u8; 64]);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.bytes_used(), 0);
        // the matrix id is still live: read-ahead completions still land
        let pass = c.begin_pass();
        c.insert_prefetched(h.matrix_id, 0, vec![1u8; 64], pass.id());
        assert!(c.contains(h.matrix_id, 0));
    }

    #[test]
    fn stale_pass_prefetch_lands_unpinned() {
        let c = cache(200);
        let h = CacheHandle::register(Arc::clone(&c));
        let id = h.matrix_id;
        let pass = c.begin_pass();
        let stale = pass.id();
        drop(pass); // the issuing pass ended
        // a late read-ahead completion: still useful bytes, but with no
        // consumer left it must not carry a pin nothing will release
        c.insert_prefetched(id, 0, vec![1u8; 100], stale);
        assert!(c.contains(id, 0));
        c.insert(id, 1, vec![0u8; 100]);
        c.insert(id, 2, vec![0u8; 100]); // pressure: (id,0) must be evictable
        assert!(!c.contains(id, 0), "stale read-ahead must land unpinned");
        assert!(c.contains(id, 1) && c.contains(id, 2));
    }

    #[test]
    fn concurrent_pass_end_keeps_other_pass_prefetch_pinned() {
        // the PR 9 bugfix pinned: with a cache-global epoch, pass B
        // ending retired pass A's read-aheads and dropped their pins —
        // per-pass ids must keep A's prefetch pinned until A consumes
        // it (or A itself ends)
        let c = cache(200);
        let h = CacheHandle::register(Arc::clone(&c));
        let id = h.matrix_id;
        let pass_a = c.begin_pass();
        let pass_b = c.begin_pass();
        assert_ne!(pass_a.id(), pass_b.id());
        c.insert_prefetched(id, 0, vec![7u8; 100], pass_a.id());
        drop(pass_b); // a concurrent pass ends — must not touch A's pins
        c.insert(id, 1, vec![0u8; 100]);
        c.insert(id, 2, vec![0u8; 100]); // pressure
        assert!(
            c.contains(id, 0),
            "pass B ending must not unpin pass A's read-ahead"
        );
        assert!(!c.contains(id, 1), "the unpinned entry is the victim");
        // A's own end is what retires its late completions...
        let stale = pass_a.id();
        drop(pass_a);
        c.insert_prefetched(id, 3, vec![1u8; 100], stale);
        // ...and the per-matrix sweep is what releases the consumed pin
        c.release_prefetch_pins(id);
        c.insert(id, 4, vec![0u8; 100]);
        c.insert(id, 5, vec![0u8; 100]);
        assert!(!c.contains(id, 0), "released pin must be evictable again");
    }

    #[test]
    fn late_prefetch_for_dropped_matrix_not_admitted() {
        let c = cache(1000);
        let h = CacheHandle::register(Arc::clone(&c));
        let id = h.matrix_id;
        let pass = c.begin_pass();
        drop(h); // matrix gone; a read-ahead completing now must be dropped
        c.insert_prefetched(id, 0, vec![0u8; 64], pass.id());
        assert!(c.is_empty(), "dead-matrix prefetch was admitted");
    }

    #[test]
    fn max_concurrent_passes_gates_admission() {
        let c = cache(1000);
        c.set_max_concurrent_passes(1);
        let first = c.begin_pass();
        let c2 = Arc::clone(&c);
        let (tx, rx) = std::sync::mpsc::channel();
        let t = std::thread::spawn(move || {
            let g = c2.begin_pass(); // must block until `first` drops
            tx.send(()).unwrap();
            drop(g);
        });
        assert!(
            rx.recv_timeout(std::time::Duration::from_millis(100)).is_err(),
            "second pass must wait for the admission slot"
        );
        drop(first);
        rx.recv_timeout(std::time::Duration::from_secs(5))
            .expect("blocked pass must be admitted once the slot frees");
        t.join().unwrap();
    }

    // -- multi-tenant fair share --------------------------------------------

    #[test]
    fn fair_share_streaming_tenant_evicts_itself_first() {
        let c = cache(400);
        let ma = Arc::new(Metrics::new());
        let mb = Arc::new(Metrics::new());
        let sa = c.register_session(Arc::clone(&ma), 200);
        let sb = c.register_session(Arc::clone(&mb), 200);
        c.set_matrix_owner(1, sa);
        c.set_matrix_owner(2, sb);
        // tenant A's hot set sits exactly at its 200 B share
        c.insert(1, 0, vec![0u8; 100]);
        c.insert(1, 1, vec![0u8; 100]);
        assert_eq!(c.session_resident_bytes(sa), 200);
        // tenant B streams 3 partitions through a full cache: victims
        // must be B's own older entries, never A's in-budget hot set
        c.insert(2, 0, vec![0u8; 100]);
        c.insert(2, 1, vec![0u8; 100]);
        c.insert(2, 2, vec![0u8; 100]);
        assert!(c.contains(1, 0) && c.contains(1, 1), "A's hot set was flushed");
        assert!(!c.contains(2, 0), "B's own LRU entry is the victim");
        assert!(c.contains(2, 2));
        assert_eq!(ma.snapshot().cache_cross_evictions, 0);
        assert_eq!(mb.snapshot().cache_cross_evictions, 0);
    }

    #[test]
    fn fair_share_over_budget_tenant_is_cross_evicted_and_charged() {
        let c = cache(400);
        let ma = Arc::new(Metrics::new());
        let mb = Arc::new(Metrics::new());
        let sa = c.register_session(Arc::clone(&ma), 100);
        let sb = c.register_session(Arc::clone(&mb), 300);
        c.set_matrix_owner(1, sa);
        c.set_matrix_owner(2, sb);
        // tenant A overruns its 100 B share with 400 B
        for p in 0..4 {
            c.insert(1, p, vec![0u8; 100]);
        }
        // tenant B inserting may displace the over-budget tenant; the
        // cross-tenant eviction is charged to the victim (A)
        c.insert(2, 0, vec![0u8; 100]);
        assert!(c.contains(2, 0));
        assert_eq!(c.session_resident_bytes(sa), 300);
        assert_eq!(ma.snapshot().cache_cross_evictions, 1);
        assert_eq!(mb.snapshot().cache_cross_evictions, 0);
        // per-tenant hit/miss attribution: A's lookups land on A's metrics
        assert!(c.get(1, 3).is_some());
        assert!(c.get(2, 9).is_none());
        assert_eq!(ma.snapshot().cache_hits, 1);
        assert_eq!(mb.snapshot().cache_misses, 1);
        // unregistering a tenant reverts its matrices to the root tenant
        c.unregister_session(sa);
        assert_eq!(c.session_count(), 1);
        assert_eq!(c.session_resident_bytes(sa), 0);
    }

    #[test]
    fn clear_resets_tenant_residency_ledger() {
        let c = cache(1000);
        let sa = c.register_session(Arc::new(Metrics::new()), 0);
        c.set_matrix_owner(5, sa);
        c.insert(5, 0, vec![0u8; 64]);
        assert_eq!(c.session_resident_bytes(sa), 64);
        c.clear();
        assert_eq!(c.session_resident_bytes(sa), 0);
        c.insert(5, 1, vec![0u8; 32]);
        assert_eq!(c.session_resident_bytes(sa), 32);
    }

    // -- clear() concurrent safety (hand-rolled stress, std-only) -----------

    #[test]
    fn clear_races_single_flight_reads_without_corruption() {
        // clear() while single-flight reads are landing: registrations
        // survive, byte accounting stays exact, and every reader still
        // gets its bytes (from the cache or its own read)
        let c = cache(64 << 10);
        let h = CacheHandle::register(Arc::clone(&c));
        let id = h.matrix_id;
        std::thread::scope(|s| {
            for t in 0..4 {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for i in 0..200 {
                        let part = (t * 200 + i) % 16;
                        let b = c
                            .get_or_read(id, part, || Ok(vec![part as u8; 128]))
                            .unwrap();
                        assert_eq!(b[0], part as u8);
                    }
                });
            }
            let c = Arc::clone(&c);
            s.spawn(move || {
                for _ in 0..100 {
                    c.clear();
                    std::thread::yield_now();
                }
            });
        });
        // accounting must still be exact after the dust settles
        let g = c.inner.lock_recover();
        let recomputed: usize = g.map.values().map(|e| e.bytes.len()).sum();
        assert_eq!(g.bytes_used, recomputed, "bytes_used drifted from the map");
        assert!(g.live.contains(&id), "clear() must keep registrations");
    }

    #[test]
    fn clear_while_other_tenant_holds_pins_stays_consistent() {
        // a second session pinning entries while another clears: clear
        // drops everything (pins are advisory for eviction, not clear),
        // but pin/unpin racing clear must never corrupt accounting
        let c = cache(64 << 10);
        let sa = c.register_session(Arc::new(Metrics::new()), 0);
        let h = CacheHandle::register(Arc::clone(&c));
        let id = h.matrix_id;
        c.set_matrix_owner(id, sa);
        std::thread::scope(|s| {
            {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for i in 0..500usize {
                        let part = i % 8;
                        c.insert(id, part, vec![1u8; 256]);
                        if c.pin(id, part) {
                            std::thread::yield_now();
                            c.unpin(id, part);
                        }
                    }
                });
            }
            let c = Arc::clone(&c);
            s.spawn(move || {
                for _ in 0..100 {
                    c.clear();
                    std::thread::yield_now();
                }
            });
        });
        c.clear();
        assert_eq!(c.bytes_used(), 0);
        assert_eq!(c.session_resident_bytes(sa), 0);
        // the pipeline still works end to end after the race
        c.insert(id, 0, vec![3u8; 64]);
        assert_eq!(c.get(id, 0).unwrap()[0], 3);
    }

    // -- write-back pipeline ------------------------------------------------

    use crate::config::ThrottleConfig;

    /// Store with an optional symmetric bandwidth throttle: the token
    /// bucket's 1-second burst means a write larger than `bps` bytes
    /// deterministically keeps the writer thread busy, which is what the
    /// coalesce/capacity/discard tests below rely on.
    fn wb_store(
        dir: &std::path::Path,
        len: u64,
        bps: Option<u64>,
        metrics: &Arc<Metrics>,
    ) -> Arc<FileStore> {
        let cfg = bps.map(|b| ThrottleConfig {
            read_bytes_per_sec: b,
            write_bytes_per_sec: b,
        });
        let ssd = Arc::new(SsdSim::new(cfg.as_ref()));
        Arc::new(FileStore::create(dir, None, len, ssd, Arc::clone(metrics)).unwrap())
    }

    #[test]
    fn writeback_flush_lands_bytes_on_file() {
        let dir = crate::testutil::TempDir::new("wb-flush");
        let metrics = Arc::new(Metrics::new());
        let c = PartitionCache::new(1024, 0, 1 << 20, Arc::clone(&metrics));
        assert!(c.writeback_enabled());
        let store = wb_store(dir.path(), 64, None, &metrics);
        let id = c.alloc_wb_id();
        assert!(c.enqueue_write(&store, id, 0, 0, Arc::new(vec![7u8; 16])));
        assert!(c.enqueue_write(&store, id, 1, 16, Arc::new(vec![9u8; 16])));
        c.flush_writes(id).unwrap();
        let mut back = [0u8; 32];
        store.read_at(0, &mut back).unwrap();
        assert_eq!(&back[..16], &[7u8; 16]);
        assert_eq!(&back[16..], &[9u8; 16]);
        let s = metrics.snapshot();
        assert_eq!(s.wb_enqueued, 2);
        assert_eq!(s.wb_discarded, 0);
    }

    #[test]
    fn writeback_coalesces_rewrite_of_queued_partition() {
        let dir = crate::testutil::TempDir::new("wb-coalesce");
        let metrics = Arc::new(Metrics::new());
        let c = PartitionCache::new(1024, 0, 1 << 20, Arc::clone(&metrics));
        // the 128 KiB head write keeps the writer busy past the 64 KiB
        // burst, so the re-write of partition 1 is still queued
        let store = wb_store(dir.path(), 256 << 10, Some(64 << 10), &metrics);
        let id = c.alloc_wb_id();
        assert!(c.enqueue_write(&store, id, 0, 0, Arc::new(vec![8u8; 128 << 10])));
        assert!(c.enqueue_write(&store, id, 1, 128 << 10, Arc::new(vec![1u8; 16])));
        assert!(c.enqueue_write(&store, id, 1, 128 << 10, Arc::new(vec![2u8; 16])));
        c.flush_writes(id).unwrap();
        let mut back = [0u8; 16];
        store.read_at(128 << 10, &mut back).unwrap();
        assert_eq!(back, [2u8; 16], "newest bytes must win");
        let s = metrics.snapshot();
        assert_eq!(s.wb_coalesced, 1, "re-write must coalesce, not re-queue");
        assert_eq!(s.wb_enqueued, 2, "coalesced write is one file write");
    }

    #[test]
    fn writeback_capacity_blocks_enqueuer_until_drained() {
        let dir = crate::testutil::TempDir::new("wb-capacity");
        let metrics = Arc::new(Metrics::new());
        // dirty bound of 1000 B: the second 700 B partition must wait for
        // the first one's (throttled: 512 B/s, 512 B burst) write
        let c = PartitionCache::new(1024, 0, 1000, Arc::clone(&metrics));
        let store = wb_store(dir.path(), 2048, Some(512), &metrics);
        let id = c.alloc_wb_id();
        let t0 = std::time::Instant::now();
        assert!(c.enqueue_write(&store, id, 0, 0, Arc::new(vec![4u8; 700])));
        assert!(c.enqueue_write(&store, id, 1, 700, Arc::new(vec![5u8; 700])));
        assert!(
            t0.elapsed().as_secs_f64() > 0.15,
            "second enqueue must block on the dirty-capacity bound"
        );
        c.flush_writes(id).unwrap();
        assert!(metrics.snapshot().wb_flush_waits >= 1);
        let mut back = vec![0u8; 1400];
        store.read_at(0, &mut back).unwrap();
        assert!(back[..700].iter().all(|b| *b == 4));
        assert!(back[700..].iter().all(|b| *b == 5));
    }

    #[test]
    fn writeback_discard_is_scoped_and_leaves_no_writes() {
        let dir = crate::testutil::TempDir::new("wb-discard");
        let metrics = Arc::new(Metrics::new());
        let c = PartitionCache::new(1024, 0, 1 << 20, Arc::clone(&metrics));
        // head write (700 B vs 512 B burst) keeps the doomed matrix's
        // writes queued until the discard below
        let store = wb_store(dir.path(), 2048, Some(512), &metrics);
        let keep = c.alloc_wb_id();
        let doomed = c.alloc_wb_id();
        assert!(c.enqueue_write(&store, keep, 0, 0, Arc::new(vec![6u8; 700])));
        assert!(c.enqueue_write(&store, doomed, 0, 1024, Arc::new(vec![3u8; 8])));
        assert!(c.enqueue_write(&store, doomed, 1, 1032, Arc::new(vec![3u8; 8])));
        c.discard_writes(doomed);
        assert_eq!(metrics.snapshot().wb_discarded, 2);
        c.flush_writes(keep).unwrap();
        let mut back = [9u8; 16];
        store.read_at(1024, &mut back).unwrap();
        assert_eq!(back, [0u8; 16], "discarded writes must never land");
        let mut head = [0u8; 4];
        store.read_at(0, &mut head).unwrap();
        assert_eq!(head, [6u8; 4], "other matrices' writes are untouched");
    }

    #[test]
    fn writeback_tenant_split_blocks_only_the_bursting_tenant() {
        let dir = crate::testutil::TempDir::new("wb-tenant");
        let metrics = Arc::new(Metrics::new());
        // dirty bound 2000 B, two tenants -> 1000 B split each
        let c = PartitionCache::new(1024, 0, 2000, Arc::clone(&metrics));
        let sa = c.register_session(Arc::new(Metrics::new()), 0);
        let sb = c.register_session(Arc::new(Metrics::new()), 0);
        let a = c.alloc_wb_id();
        let b = c.alloc_wb_id();
        c.set_matrix_owner(a, sa);
        c.set_matrix_owner(b, sb);
        // throttle (512 B/s, 512 B burst): each 700 B write keeps the
        // writer busy long enough for the admission checks to observe
        let store = wb_store(dir.path(), 4096, Some(512), &metrics);
        assert!(c.enqueue_write(&store, a, 0, 0, Arc::new(vec![1u8; 700])));
        let c2 = Arc::clone(&c);
        let store2 = Arc::clone(&store);
        let t = std::thread::spawn(move || {
            let t0 = std::time::Instant::now();
            // tenant A overruns its 1000 B split (700 + 700): must wait
            assert!(c2.enqueue_write(&store2, a, 1, 700, Arc::new(vec![2u8; 700])));
            t0.elapsed()
        });
        // tenant B is within its split AND the global bound: no wait
        let t0 = std::time::Instant::now();
        assert!(c.enqueue_write(&store, b, 0, 1400, Arc::new(vec![3u8; 700])));
        let b_wait = t0.elapsed();
        let a_wait = t.join().unwrap();
        assert!(
            a_wait.as_secs_f64() > 0.15,
            "bursting tenant must block on its dirty split (waited {a_wait:?})"
        );
        assert!(
            b_wait < a_wait,
            "the in-budget tenant must not pay the burster's wait"
        );
        c.flush_writes(a).unwrap();
        c.flush_writes(b).unwrap();
    }

    #[test]
    fn writeback_flush_propagates_write_error_once() {
        let dir = crate::testutil::TempDir::new("wb-err");
        let metrics = Arc::new(Metrics::new());
        let c = PartitionCache::new(1024, 0, 1 << 20, Arc::clone(&metrics));
        let store = wb_store(dir.path(), 8, None, &metrics);
        let id = c.alloc_wb_id();
        // past-end write: the background writer fails, the barrier reports
        assert!(c.enqueue_write(&store, id, 0, 0, Arc::new(vec![1u8; 64])));
        assert!(c.flush_writes(id).is_err());
        // the error was taken; the pipeline stays usable
        assert!(c.enqueue_write(&store, id, 1, 0, Arc::new(vec![2u8; 8])));
        c.flush_writes(id).unwrap();
    }

    #[test]
    fn writeback_disabled_falls_back_to_caller() {
        let dir = crate::testutil::TempDir::new("wb-off");
        let metrics = Arc::new(Metrics::new());
        let c = cache(1024); // writeback_queue_bytes = 0
        assert!(!c.writeback_enabled());
        let store = wb_store(dir.path(), 64, None, &metrics);
        assert!(!c.enqueue_write(&store, 0, 0, 0, Arc::new(vec![1u8; 8])));
        c.flush_writes(0).unwrap();
        c.discard_writes(0);
    }

    #[test]
    fn writeback_drains_pending_writes_on_cache_drop() {
        let dir = crate::testutil::TempDir::new("wb-drop");
        let metrics = Arc::new(Metrics::new());
        let c = PartitionCache::new(1024, 0, 1 << 20, Arc::clone(&metrics));
        let store = wb_store(dir.path(), 64, None, &metrics);
        let id = c.alloc_wb_id();
        assert!(c.enqueue_write(&store, id, 0, 0, Arc::new(vec![5u8; 16])));
        drop(c); // shutdown: the writer must drain, not drop, the queue
        let mut back = [0u8; 16];
        for _ in 0..2000 {
            store.read_at(0, &mut back).unwrap();
            if back == [5u8; 16] {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(back, [5u8; 16], "pending write lost at shutdown");
    }
}
