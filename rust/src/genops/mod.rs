//! The four generalized matrix operators (GenOps), paper §III-C / Table I.
//!
//! Every function here *records* computation: it shape-checks its operands,
//! resolves output dtype (inserting lazy casts per the paper's promotion
//! rule), and returns a virtual matrix ([`VKind`]) or a [`SinkSpec`].
//! Nothing executes until [`crate::exec`] materializes the DAG.
//!
//! Transposed (wide) views are normalized here, exactly as §III-G's
//! layout-driven form selection prescribes:
//! * elementwise ops commute with transposition — `sapply(t(A))` is
//!   recorded as `t(sapply(A))`;
//! * `agg.row` on a wide view becomes `agg.col` on the canonical TAS data
//!   (a sink) while on a tall matrix it stays an in-DAG per-row reduction;
//! * `inner.prod(t(A), B)` with both operands sharing the long dimension
//!   becomes the wide×tall sink; `inner.prod(A, small)` stays in the DAG.
//!
//! # Example
//!
//! The `fmr` layer wraps exactly these calls; recording a DAG through it
//! and forcing a sink runs the whole chain in one fused parallel pass:
//!
//! ```
//! use flashmatrix::fmr::{Engine, EngineExt, FmMatrix};
//! use flashmatrix::vudf::AggOp;
//! use flashmatrix::EngineConfig;
//!
//! let eng = Engine::new(EngineConfig {
//!     xla_dispatch: false,
//!     ..Default::default()
//! })
//! .unwrap();
//! let x = eng.runif_matrix(10_000, 4, 0.0, 1.0, 7);
//! let total = x.sq().unwrap().agg(AggOp::Sum).unwrap().as_f64();
//! assert!(total > 0.0 && total < 10_000.0 * 4.0);
//! ```

use crate::dag::{SinkKind, SinkSpec, UnFn, VKind, VNode};
use crate::dtype::{DType, Scalar};
use crate::error::{FmError, Result};
use crate::matrix::{HostMat, Matrix, MatrixData};
use crate::vudf::{AggOp, BinOp, NaMode};

fn vmat(nrow: u64, ncol: u64, dtype: DType, kind: VKind) -> Matrix {
    Matrix::new(MatrixData::Virtual(VNode {
        nrow,
        ncol,
        dtype,
        kind,
    }))
}

/// Insert a lazy cast node if `m`'s dtype differs from `to` (§III-D).
///
/// # Examples
///
/// ```
/// use flashmatrix::dtype::DType;
/// use flashmatrix::genops;
/// # use flashmatrix::dag::{VKind, VNode};
/// # use flashmatrix::dtype::Scalar;
/// # use flashmatrix::matrix::{Matrix, MatrixData};
/// # let a = Matrix::new(MatrixData::Virtual(VNode {
/// #     nrow: 8, ncol: 2, dtype: DType::I32,
/// #     kind: VKind::Fill(Scalar::I32(1)),
/// # }));
/// let c = genops::cast(&a, DType::F64);
/// assert_eq!(c.dtype(), DType::F64);
/// // same-dtype casts are the identity: no node is inserted
/// assert_eq!(genops::cast(&c, DType::F64).data_ptr(), c.data_ptr());
/// ```
pub fn cast(m: &Matrix, to: DType) -> Matrix {
    if m.dtype() == to {
        return m.clone();
    }
    let c = vmat(
        m.data.nrow(),
        m.data.ncol(),
        to,
        VKind::Cast {
            a: m.canonical(),
            to,
        },
    );
    Matrix {
        data: c.data,
        transposed: m.transposed,
    }
}

/// `fm.sapply(A, f)` — elementwise unary.
///
/// # Examples
///
/// ```
/// use flashmatrix::dag::UnFn;
/// use flashmatrix::genops;
/// use flashmatrix::vudf::UnOp;
/// # use flashmatrix::dag::{VKind, VNode};
/// # use flashmatrix::dtype::{DType, Scalar};
/// # use flashmatrix::matrix::{Matrix, MatrixData};
/// # let a = Matrix::new(MatrixData::Virtual(VNode {
/// #     nrow: 8, ncol: 2, dtype: DType::F64,
/// #     kind: VKind::Fill(Scalar::F64(-1.5)),
/// # }));
/// let s = genops::sapply(&a, UnFn::Builtin(UnOp::Abs));
/// assert!(s.is_virtual()); // recorded, not computed
/// assert_eq!((s.nrow(), s.ncol()), (8, 2));
/// // elementwise ops commute with transposition (§III-G)
/// let st = genops::sapply(&a.t(), UnFn::Builtin(UnOp::Abs));
/// assert_eq!((st.nrow(), st.ncol()), (2, 8));
/// ```
pub fn sapply(a: &Matrix, op: UnFn) -> Matrix {
    let dt = op.out_dtype(a.dtype());
    let v = vmat(
        a.data.nrow(),
        a.data.ncol(),
        dt,
        VKind::Sapply {
            a: a.canonical(),
            op,
        },
    );
    Matrix {
        data: v.data,
        transposed: a.transposed,
    }
}

/// `fm.mapply(A, B, f)` — elementwise binary. Operands must agree on the
/// *view* shape; differing dtypes promote via lazy casts.
///
/// # Examples
///
/// ```
/// use flashmatrix::genops;
/// use flashmatrix::vudf::BinOp;
/// # use flashmatrix::dag::{VKind, VNode};
/// # use flashmatrix::dtype::{DType, Scalar};
/// # use flashmatrix::matrix::{Matrix, MatrixData};
/// # let fill = |nrow, ncol, dt: DType, s: Scalar| Matrix::new(
/// #     MatrixData::Virtual(VNode { nrow, ncol, dtype: dt, kind: VKind::Fill(s) }));
/// # let a = fill(8, 2, DType::I32, Scalar::I32(3));
/// # let b = fill(8, 2, DType::F64, Scalar::F64(0.5));
/// # let short = fill(5, 2, DType::F64, Scalar::F64(0.0));
/// // i32 + f64 promotes to f64 through lazy casts
/// let sum = genops::mapply(&a, &b, BinOp::Add).unwrap();
/// assert_eq!(sum.dtype(), flashmatrix::dtype::DType::F64);
/// // shape mismatches are rejected at record time
/// assert!(genops::mapply(&a, &short, BinOp::Add).is_err());
/// ```
pub fn mapply(a: &Matrix, b: &Matrix, op: BinOp) -> Result<Matrix> {
    if a.nrow() != b.nrow() || a.ncol() != b.ncol() {
        return Err(FmError::Shape(format!(
            "mapply shape mismatch: {}x{} vs {}x{}",
            a.nrow(),
            a.ncol(),
            b.nrow(),
            b.ncol()
        )));
    }
    if a.transposed != b.transposed {
        return Err(FmError::Unsupported(
            "mapply on mixed-layout views; call fm.conv.layout first".into(),
        ));
    }
    let t = DType::promote(a.dtype(), b.dtype());
    let (ca, cb) = (cast(a, t), cast(b, t));
    let dt = op.out_dtype(t);
    let v = vmat(
        a.data.nrow(),
        a.data.ncol(),
        dt,
        VKind::Mapply {
            a: ca.canonical(),
            b: cb.canonical(),
            op,
        },
    );
    Ok(Matrix {
        data: v.data,
        transposed: a.transposed,
    })
}

/// `fm.mapply` against a scalar (bVUDF2/bVUDF3 selection).
pub fn mapply_scalar(a: &Matrix, s: Scalar, op: BinOp, scalar_right: bool) -> Matrix {
    let t = DType::promote(a.dtype(), s.dtype());
    let ca = cast(a, t);
    let dt = op.out_dtype(t);
    let v = vmat(
        a.data.nrow(),
        a.data.ncol(),
        dt,
        VKind::MapplyScalar {
            a: ca.canonical(),
            s: s.cast(t),
            op,
            scalar_right,
        },
    );
    Matrix {
        data: v.data,
        transposed: a.transposed,
    }
}

/// `fm.mapply.row(A, w, f)` — each row combined with the small vector `w`
/// (len = view ncol). On a wide (transposed) view this is `mapply.col` on
/// the canonical data.
pub fn mapply_row(a: &Matrix, w: &HostMat, op: BinOp) -> Result<Matrix> {
    if a.transposed {
        // rows of the view are columns of the canonical data
        return Err(FmError::Unsupported(
            "mapply.row on a wide view: use mapply.col on the base matrix".into(),
        ));
    }
    if w.nrow * w.ncol != a.ncol() as usize {
        return Err(FmError::Shape(format!(
            "mapply.row: vector has {} elements, matrix has {} columns",
            w.nrow * w.ncol,
            a.ncol()
        )));
    }
    let t = DType::promote(a.dtype(), w.buf.dtype());
    let ca = cast(a, t);
    let w2 = HostMat {
        nrow: w.nrow,
        ncol: w.ncol,
        buf: w.buf.cast(t)?,
    };
    let dt = op.out_dtype(t);
    Ok(vmat(
        a.data.nrow(),
        a.data.ncol(),
        dt,
        VKind::MapplyRow {
            a: ca.canonical(),
            w: w2,
            op,
        },
    ))
}

/// `fm.mapply.col(A, v, f)` — each column combined with an n×1 matrix
/// sharing the long dimension (`v` may itself be virtual, so whole
/// normalization pipelines fuse into one pass).
pub fn mapply_col(a: &Matrix, v: &Matrix, op: BinOp) -> Result<Matrix> {
    if a.transposed {
        return Err(FmError::Unsupported(
            "mapply.col on a wide view: use mapply.row on the base matrix".into(),
        ));
    }
    if v.ncol() != 1 || v.nrow() != a.nrow() {
        return Err(FmError::Shape(format!(
            "mapply.col: vector must be {}x1, got {}x{}",
            a.nrow(),
            v.nrow(),
            v.ncol()
        )));
    }
    let t = DType::promote(a.dtype(), v.dtype());
    let (ca, cv) = (cast(a, t), cast(v, t));
    let dt = op.out_dtype(t);
    Ok(vmat(
        a.data.nrow(),
        a.data.ncol(),
        dt,
        VKind::MapplyCol {
            a: ca.canonical(),
            v: cv.canonical(),
            op,
        },
    ))
}

/// `A[, j]` — select one column (lazy, stays in the DAG).
pub fn select_col(a: &Matrix, col: u64) -> Result<Matrix> {
    if a.transposed {
        return Err(FmError::Unsupported("column select on a wide view".into()));
    }
    if col >= a.ncol() {
        return Err(FmError::Shape(format!(
            "column {col} out of range (ncol = {})",
            a.ncol()
        )));
    }
    Ok(vmat(
        a.data.nrow(),
        1,
        a.dtype(),
        VKind::SelectCol {
            a: a.canonical(),
            col,
        },
    ))
}

/// Column concatenation of same-long-dim matrices (virtual cbind).
pub fn colbind(ms: &[Matrix]) -> Result<Matrix> {
    if ms.is_empty() {
        return Err(FmError::Shape("cbind of zero matrices".into()));
    }
    let nrow = ms[0].nrow();
    let mut dt = ms[0].dtype();
    for m in ms {
        if m.transposed {
            return Err(FmError::Unsupported("cbind of wide views".into()));
        }
        if m.nrow() != nrow {
            return Err(FmError::Shape("cbind row-count mismatch".into()));
        }
        dt = DType::promote(dt, m.dtype());
    }
    let ncol: u64 = ms.iter().map(|m| m.ncol()).sum();
    Ok(vmat(
        nrow,
        ncol,
        dt,
        VKind::ColBind(ms.iter().map(|m| m.canonical()).collect()),
    ))
}

/// `fm.agg.row(A, f)` on a tall matrix: per-row reduction, stays in-DAG.
/// On a wide (transposed) view: per-row of the view = per-column of the
/// canonical data -> a sink.
pub enum RowAggResult {
    /// Tall input: n×1 virtual matrix.
    InDag(Matrix),
    /// Wide view: sink producing 1×n host result.
    Sink(SinkSpec),
}

/// # Examples
///
/// ```
/// use flashmatrix::genops::{self, RowAggResult};
/// use flashmatrix::vudf::AggOp;
/// # use flashmatrix::dag::{VKind, VNode};
/// # use flashmatrix::dtype::{DType, Scalar};
/// # use flashmatrix::matrix::{Matrix, MatrixData};
/// # let a = Matrix::new(MatrixData::Virtual(VNode {
/// #     nrow: 8, ncol: 2, dtype: DType::F64,
/// #     kind: VKind::Fill(Scalar::F64(1.0)),
/// # }));
/// // tall matrix: the per-row reduction keeps the long dimension and
/// // stays in the DAG as an 8x1 node
/// match genops::agg_row(&a, AggOp::Sum) {
///     RowAggResult::InDag(v) => assert_eq!((v.nrow(), v.ncol()), (8, 1)),
///     RowAggResult::Sink(_) => unreachable!("tall agg.row stays in the DAG"),
/// }
/// // wide (transposed) view: rows of the view are columns of the
/// // canonical data, so this becomes a column-aggregation sink
/// assert!(matches!(
///     genops::agg_row(&a.t(), AggOp::Sum),
///     RowAggResult::Sink(_)
/// ));
/// ```
pub fn agg_row(a: &Matrix, op: AggOp) -> RowAggResult {
    agg_row_na(a, op, NaMode::Off)
}

/// [`agg_row`] with explicit NA handling (R's `na.rm=`; see
/// [`NaMode`]).
pub fn agg_row_na(a: &Matrix, op: AggOp, na: NaMode) -> RowAggResult {
    if a.transposed {
        RowAggResult::Sink(SinkSpec {
            source: a.canonical(),
            kind: SinkKind::AggCol(op, na),
        })
    } else {
        let dt = op.acc_dtype(a.dtype());
        RowAggResult::InDag(vmat(
            a.data.nrow(),
            1,
            dt,
            VKind::RowAgg {
                a: a.canonical(),
                op,
                na,
            },
        ))
    }
}

/// `fm.agg.col(A, f)` on a tall matrix: sink. On a wide view: in-DAG
/// per-row reduction of the canonical data.
pub fn agg_col(a: &Matrix, op: AggOp) -> RowAggResult {
    agg_col_na(a, op, NaMode::Off)
}

/// [`agg_col`] with explicit NA handling.
pub fn agg_col_na(a: &Matrix, op: AggOp, na: NaMode) -> RowAggResult {
    if a.transposed {
        let dt = op.acc_dtype(a.dtype());
        RowAggResult::InDag(vmat(
            a.data.nrow(),
            1,
            dt,
            VKind::RowAgg {
                a: a.canonical(),
                op,
                na,
            },
        ))
    } else {
        RowAggResult::Sink(SinkSpec {
            source: a.canonical(),
            kind: SinkKind::AggCol(op, na),
        })
    }
}

/// `fm.agg(A, f)` — whole-matrix reduction (sink).
///
/// # Examples
///
/// ```
/// use flashmatrix::dag::SinkKind;
/// use flashmatrix::genops;
/// use flashmatrix::vudf::AggOp;
/// # use flashmatrix::dag::{VKind, VNode};
/// # use flashmatrix::dtype::{DType, Scalar};
/// # use flashmatrix::matrix::{Matrix, MatrixData};
/// # let a = Matrix::new(MatrixData::Virtual(VNode {
/// #     nrow: 8, ncol: 2, dtype: DType::F64,
/// #     kind: VKind::Fill(Scalar::F64(1.0)),
/// # }));
/// let sink = genops::agg_full(&a, AggOp::Max);
/// assert!(matches!(sink.kind, SinkKind::AggFull(AggOp::Max, _)));
/// ```
pub fn agg_full(a: &Matrix, op: AggOp) -> SinkSpec {
    agg_full_na(a, op, NaMode::Off)
}

/// [`agg_full`] with explicit NA handling (R's `na.rm=`).
pub fn agg_full_na(a: &Matrix, op: AggOp, na: NaMode) -> SinkSpec {
    SinkSpec {
        source: a.canonical(),
        kind: SinkKind::AggFull(op, na),
    }
}

/// Row index (1-based) of the per-row minimum / maximum — `which.min` /
/// `which.max` applied row-wise; the k-means assignment op. NaNs are
/// skipped like R's NAs; an all-NaN row yields the NA index 0 (R returns
/// no index there), which the `labels - 1` + `fm.groupby.row` pipeline
/// drops like R drops NA groups.
pub fn which_extreme_row(a: &Matrix, max: bool) -> Result<Matrix> {
    if a.transposed {
        return Err(FmError::Unsupported(
            "which.min/max over a wide view".into(),
        ));
    }
    Ok(vmat(
        a.data.nrow(),
        1,
        DType::I32,
        VKind::RowArgExtreme {
            a: a.canonical(),
            max,
        },
    ))
}

/// `fm.groupby.row(A, labels, f)` — labels are an n×1 integer matrix with
/// values in `0..k` (out-of-range rows are dropped); returns a sink
/// producing k×ncol.
///
/// # Examples
///
/// ```
/// use flashmatrix::dag::SinkKind;
/// use flashmatrix::genops;
/// use flashmatrix::vudf::AggOp;
/// # use flashmatrix::dag::{VKind, VNode};
/// # use flashmatrix::dtype::{DType, Scalar};
/// # use flashmatrix::matrix::{Matrix, MatrixData};
/// # let a = Matrix::new(MatrixData::Virtual(VNode {
/// #     nrow: 8, ncol: 2, dtype: DType::F64,
/// #     kind: VKind::Fill(Scalar::F64(1.0)),
/// # }));
/// # let labels = Matrix::new(MatrixData::Virtual(VNode {
/// #     nrow: 8, ncol: 1, dtype: DType::I32,
/// #     kind: VKind::Fill(Scalar::I32(0)),
/// # }));
/// // the k-means update: per-cluster sums in one pass
/// let s = genops::groupby_row(&a, &labels, 4, AggOp::Sum).unwrap();
/// assert!(matches!(s.kind, SinkKind::GroupByRow { k: 4, .. }));
/// ```
pub fn groupby_row(a: &Matrix, labels: &Matrix, k: usize, op: AggOp) -> Result<SinkSpec> {
    if labels.ncol() != 1 || labels.nrow() != a.nrow() {
        return Err(FmError::Shape(format!(
            "groupby.row labels must be {}x1, got {}x{}",
            a.nrow(),
            labels.nrow(),
            labels.ncol()
        )));
    }
    Ok(SinkSpec {
        source: a.canonical(),
        kind: SinkKind::GroupByRow {
            labels: cast(&labels.canonical(), DType::I32),
            k,
            op,
        },
    })
}

/// `fm.inner.prod(A, B, f1, f2)`, tall × small: A is n×p (tall), `b` is a
/// small p×q host matrix. Stays in the DAG (output is n×q, same long dim).
///
/// # Examples
///
/// ```
/// use flashmatrix::genops;
/// use flashmatrix::matrix::HostMat;
/// use flashmatrix::vudf::{AggOp, BinOp};
/// # use flashmatrix::dag::{VKind, VNode};
/// # use flashmatrix::dtype::{DType, Scalar};
/// # use flashmatrix::matrix::{Matrix, MatrixData};
/// # let a = Matrix::new(MatrixData::Virtual(VNode {
/// #     nrow: 8, ncol: 2, dtype: DType::F64,
/// #     kind: VKind::Fill(Scalar::F64(1.0)),
/// # }));
/// // ordinary matmul is inner.prod with (*, +): 8x2 ⊗ 2x3 -> 8x3
/// let b = HostMat::from_rows_f64(&[vec![1.0, 0.0, 2.0], vec![0.0, 1.0, 3.0]]);
/// let y = genops::inner_small(&a, &b, BinOp::Mul, AggOp::Sum).unwrap();
/// assert_eq!((y.nrow(), y.ncol()), (8, 3));
/// assert!(y.is_virtual());
/// ```
pub fn inner_small(a: &Matrix, b: &HostMat, f1: BinOp, f2: AggOp) -> Result<Matrix> {
    if a.transposed {
        return Err(FmError::Unsupported(
            "inner.prod: left operand is a wide view; use inner_wide_tall".into(),
        ));
    }
    if a.ncol() as usize != b.nrow {
        return Err(FmError::Shape(format!(
            "inner.prod: {}x{} × {}x{}",
            a.nrow(),
            a.ncol(),
            b.nrow,
            b.ncol
        )));
    }
    let dt = f2.acc_dtype(DType::promote(a.dtype(), b.buf.dtype()));
    Ok(vmat(
        a.data.nrow(),
        b.ncol as u64,
        dt,
        VKind::InnerSmall {
            a: a.canonical(),
            b: b.clone(),
            f1,
            f2,
        },
    ))
}

/// Streaming SpMM: sparse tall `a` (n×m CSR row-partitions) × small dense
/// host `b` (m×q) -> tall dense n×q, recorded lazily like every GenOp.
/// The sparse operand streams through the pass as a *source* (its CSR
/// bytes are decoded per strip); the right-hand matrix stays in memory —
/// the out-of-core PageRank shape (edges on SSD, rank vector in DRAM).
///
/// The contraction order per output element matches the dense
/// [`inner_small`] (Mul, Sum) kernel, column-ascending, so SpMM is
/// bit-identical to densify-then-`inner.prod` (the parity property test
/// pins this).
pub fn spmm(a: &Matrix, b: HostMat) -> Result<Matrix> {
    if !a.data.is_sparse() {
        return Err(FmError::Unsupported(
            "spmm: left operand must be a sparse matrix".into(),
        ));
    }
    if a.transposed {
        return Err(FmError::Unsupported(
            "spmm on a transposed sparse view".into(),
        ));
    }
    if a.ncol() as usize != b.nrow {
        return Err(FmError::Shape(format!(
            "spmm: {}x{} × {}x{}",
            a.nrow(),
            a.ncol(),
            b.nrow,
            b.ncol
        )));
    }
    // by-value operand moves into the Arc (f64 inputs copy nothing);
    // passes then share it instead of re-copying per compile
    let q = b.ncol as u64;
    let b64 = std::sync::Arc::new(if b.buf.dtype() == DType::F64 {
        b
    } else {
        HostMat {
            nrow: b.nrow,
            ncol: b.ncol,
            buf: b.buf.cast(DType::F64)?,
        }
    });
    Ok(vmat(
        a.data.nrow(),
        q,
        DType::F64,
        VKind::Spmm {
            a: a.canonical(),
            b: b64,
        },
    ))
}

/// `fm.inner.prod(t(A), B, f1, f2)`, wide × tall: both share the long
/// dimension; the p×q result is a sink (per-thread partial Gramians merged
/// with `f2`'s combine).
///
/// # Examples
///
/// ```
/// use flashmatrix::dag::SinkKind;
/// use flashmatrix::genops;
/// use flashmatrix::vudf::{AggOp, BinOp};
/// # use flashmatrix::dag::{VKind, VNode};
/// # use flashmatrix::dtype::{DType, Scalar};
/// # use flashmatrix::matrix::{Matrix, MatrixData};
/// # let fill = |nrow, ncol| Matrix::new(MatrixData::Virtual(VNode {
/// #     nrow, ncol, dtype: DType::F64, kind: VKind::Fill(Scalar::F64(1.0)),
/// # }));
/// # let a = fill(10, 2);
/// # let b = fill(10, 3);
/// // the Gramian t(A) %*% B: both operands share the long dimension
/// let g = genops::inner_wide_tall(&a.t(), &b, BinOp::Mul, AggOp::Sum).unwrap();
/// assert!(matches!(g.kind, SinkKind::InnerWideTall { .. }));
/// // the left operand must really be a wide (transposed) view
/// assert!(genops::inner_wide_tall(&a, &b, BinOp::Mul, AggOp::Sum).is_err());
/// ```
pub fn inner_wide_tall(a_t: &Matrix, b: &Matrix, f1: BinOp, f2: AggOp) -> Result<SinkSpec> {
    if !a_t.transposed {
        return Err(FmError::Unsupported(
            "inner_wide_tall: left operand must be a transposed (wide) view".into(),
        ));
    }
    if b.transposed {
        return Err(FmError::Unsupported(
            "inner_wide_tall: right operand must be tall".into(),
        ));
    }
    if a_t.ncol() != b.nrow() {
        return Err(FmError::Shape(format!(
            "inner.prod: {}x{} × {}x{} (long dims differ)",
            a_t.nrow(),
            a_t.ncol(),
            b.nrow(),
            b.ncol()
        )));
    }
    Ok(SinkSpec {
        source: a_t.canonical(),
        kind: SinkKind::InnerWideTall {
            right: b.canonical(),
            f1,
            f2,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(nrow: u64, ncol: u64, dt: DType) -> Matrix {
        vmat(nrow, ncol, dt, VKind::Fill(Scalar::F64(1.0).cast(dt)))
    }

    #[test]
    fn mapply_promotes_dtypes() {
        let a = fill(10, 2, DType::I32);
        let b = fill(10, 2, DType::F64);
        let m = mapply(&a, &b, BinOp::Add).unwrap();
        assert_eq!(m.dtype(), DType::F64);
        // a cast node was inserted under the hood
        if let MatrixData::Virtual(v) = &*m.data {
            assert_eq!(v.kind.parents().len(), 2);
        } else {
            panic!("expected virtual");
        }
    }

    #[test]
    fn transposed_elementwise_commutes() {
        let a = fill(10, 2, DType::F64).t();
        let s = sapply(&a, UnFn::Builtin(crate::vudf::UnOp::Abs));
        assert!(s.transposed);
        assert_eq!((s.nrow(), s.ncol()), (2, 10));
    }

    #[test]
    fn agg_row_wide_becomes_sink() {
        let a = fill(10, 2, DType::F64);
        match agg_row(&a, AggOp::Sum) {
            RowAggResult::InDag(v) => assert_eq!((v.nrow(), v.ncol()), (10, 1)),
            _ => panic!("tall agg.row must stay in DAG"),
        }
        match agg_row(&a.t(), AggOp::Sum) {
            RowAggResult::Sink(s) => {
                assert!(matches!(s.kind, SinkKind::AggCol(AggOp::Sum, NaMode::Off)))
            }
            _ => panic!("wide agg.row must be a sink"),
        }
    }

    #[test]
    fn shape_errors() {
        let a = fill(10, 2, DType::F64);
        let b = fill(12, 2, DType::F64);
        assert!(mapply(&a, &b, BinOp::Add).is_err());
        let w = HostMat::from_rows_f64(&[vec![1.0, 2.0, 3.0]]);
        assert!(mapply_row(&a, &w, BinOp::Add).is_err());
        let small = HostMat::from_rows_f64(&[vec![1.0], vec![2.0], vec![3.0]]);
        assert!(inner_small(&a, &small, BinOp::Mul, AggOp::Sum).is_err());
    }

    #[test]
    fn inner_wide_tall_requires_transposed_left() {
        let a = fill(10, 2, DType::F64);
        let b = fill(10, 3, DType::F64);
        assert!(inner_wide_tall(&a, &b, BinOp::Mul, AggOp::Sum).is_err());
        let s = inner_wide_tall(&a.t(), &b, BinOp::Mul, AggOp::Sum).unwrap();
        assert!(matches!(s.kind, SinkKind::InnerWideTall { .. }));
    }
}
