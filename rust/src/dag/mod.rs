//! Lazy evaluation: virtual matrices and the operation DAG (paper §III-E).
//!
//! Every GenOp returns a *virtual matrix* — a [`VNode`] recording the
//! computation and `Arc` references to its parent matrices. A chain of
//! GenOps therefore builds a DAG bottom-up for free; nothing executes until
//! [`crate::exec`] materializes target matrices / sinks, at which point the
//! whole DAG runs as **one** partition-streaming pass (operation fusion).
//!
//! Two node classes mirror the paper's:
//! * *elementwise* nodes keep the DAG's shared long dimension (`fm.sapply`,
//!   `fm.mapply*`, per-row reductions on tall matrices, inner products with
//!   a small right operand, casts, cbind) and can feed further nodes;
//! * *sink* nodes ([`SinkSpec`]) end a DAG (`fm.agg`, `fm.agg.col`,
//!   `fm.groupby.row`, wide×tall inner products); their outputs are small
//!   host matrices produced by per-thread partial aggregation + merge
//!   (§III-F).

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

use crate::dtype::{DType, Scalar};
use crate::error::{FmError, Result};
use crate::matrix::{HostMat, Matrix, MatrixData};
use crate::vudf::{AggOp, BinOp, CustomVudf, NaMode, UnOp};

/// Unary op reference: built-in (enum fast path) or registered custom VUDF.
#[derive(Clone)]
pub enum UnFn {
    Builtin(UnOp),
    Custom(Arc<dyn CustomVudf>),
}

impl UnFn {
    pub fn out_dtype(&self, input: DType) -> DType {
        match self {
            UnFn::Builtin(op) => op.out_dtype(input),
            UnFn::Custom(c) => c.out_dtype(input),
        }
    }
}

impl std::fmt::Debug for UnFn {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            UnFn::Builtin(op) => write!(f, "{op:?}"),
            UnFn::Custom(c) => write!(f, "custom:{}", c.name()),
        }
    }
}

/// A virtual matrix: shape + recorded computation.
pub struct VNode {
    /// Canonical rows — the DAG long dimension.
    pub nrow: u64,
    pub ncol: u64,
    pub dtype: DType,
    pub kind: VKind,
}

/// The recorded computation of a virtual matrix.
pub enum VKind {
    /// Every element equals a constant (e.g. `fm.rep.int`).
    Fill(Scalar),
    /// One-column sequence by global row index: `start + step*row`
    /// (`fm.seq.int`).
    Seq { start: f64, step: f64 },
    /// Counter-based uniform randomness: element (r,j) derives from
    /// `splitmix64_at(seed, r*ncol + j)` — partition-order independent
    /// (`fm.runif.matrix`).
    RandU { seed: u64, lo: f64, hi: f64 },
    /// Counter-based normal randomness via Box-Muller
    /// (`fm.rnorm.matrix`).
    RandN { seed: u64, mean: f64, sd: f64 },
    /// `fm.sapply`.
    Sapply { a: Matrix, op: UnFn },
    /// `fm.mapply` (elementwise, both operands share the long dim).
    Mapply { a: Matrix, b: Matrix, op: BinOp },
    /// `fm.mapply` against a scalar (vector ⊕ scalar forms).
    MapplyScalar {
        a: Matrix,
        s: Scalar,
        op: BinOp,
        /// true: `f(a, s)` (bVUDF2); false: `f(s, a)` (bVUDF3).
        scalar_right: bool,
    },
    /// `fm.mapply.row`: combine each row with a small host vector
    /// (len = ncol).
    MapplyRow { a: Matrix, w: HostMat, op: BinOp },
    /// `fm.mapply.col`: combine each column with an n×1 matrix sharing the
    /// long dimension (itself possibly virtual — this is what lets whole
    /// normalization pipelines fuse).
    MapplyCol { a: Matrix, v: Matrix, op: BinOp },
    /// `fm.agg.row` on a tall matrix: per-row reduction, n×1 output —
    /// stays in the DAG (paper §III-E "first type"). `na` selects the
    /// NA handling (`NaMode::Off` = legacy NA-oblivious kernels).
    RowAgg { a: Matrix, op: AggOp, na: NaMode },
    /// Per-row index of the extreme value (1-based like R's which.min);
    /// i32 output. Backs `fm.agg.row(which.min/which.max)`.
    RowArgExtreme { a: Matrix, max: bool },
    /// Generalized inner product with a *small* right operand
    /// (tall n×p ⊗ small p×q -> tall n×q): `fm.inner.prod(A, B, f1, f2)`.
    InnerSmall {
        a: Matrix,
        b: HostMat,
        f1: BinOp,
        f2: AggOp,
    },
    /// Streaming sparse × small-dense multiply (`fm.multiply` on a sparse
    /// left operand): CSR row-partitions of `a` (n×m) stream against the
    /// in-memory right operand `b` (m×q) -> tall n×q dense. `a` is a
    /// *source* like a dense input, not a register-producing node — the
    /// strip evaluator decodes its CSR bytes directly — so `parents()`
    /// does not list it. `b` sits behind an `Arc`: compiling the node
    /// into a pass must not copy the (potentially n-element) operand.
    Spmm { a: Matrix, b: Arc<HostMat> },
    /// Lazy element-type cast.
    Cast { a: Matrix, to: DType },
    /// Column concatenation of same-long-dim nodes (`fm.cbind` within a
    /// DAG).
    ColBind(Vec<Matrix>),
    /// Select one column of a node as an n×1 matrix (`A[, j]`).
    SelectCol { a: Matrix, col: u64 },
}

impl VKind {
    /// Parent matrices (DAG edges).
    pub fn parents(&self) -> Vec<&Matrix> {
        match self {
            VKind::Fill(_)
            | VKind::Seq { .. }
            | VKind::RandU { .. }
            | VKind::RandN { .. }
            | VKind::Spmm { .. } => {
                vec![]
            }
            VKind::Sapply { a, .. }
            | VKind::MapplyScalar { a, .. }
            | VKind::MapplyRow { a, .. }
            | VKind::RowAgg { a, .. }
            | VKind::RowArgExtreme { a, .. }
            | VKind::InnerSmall { a, .. }
            | VKind::Cast { a, .. }
            | VKind::SelectCol { a, .. } => vec![a],
            VKind::Mapply { a, b, .. } => vec![a, b],
            VKind::MapplyCol { a, v, .. } => vec![a, v],
            VKind::ColBind(ms) => ms.iter().collect(),
        }
    }

    /// Stable discriminant for structural node identity (the planner's
    /// hash-consing key; [`crate::plan`]).
    pub fn code(&self) -> u8 {
        match self {
            VKind::Fill(_) => 0,
            VKind::Seq { .. } => 1,
            VKind::RandU { .. } => 2,
            VKind::RandN { .. } => 3,
            VKind::Sapply { .. } => 4,
            VKind::Mapply { .. } => 5,
            VKind::MapplyScalar { .. } => 6,
            VKind::MapplyRow { .. } => 7,
            VKind::MapplyCol { .. } => 8,
            VKind::RowAgg { .. } => 9,
            VKind::RowArgExtreme { .. } => 10,
            VKind::InnerSmall { .. } => 11,
            VKind::Spmm { .. } => 12,
            VKind::Cast { .. } => 13,
            VKind::ColBind(_) => 14,
            VKind::SelectCol { .. } => 15,
        }
    }

    /// Hash the node-local parameters — everything that distinguishes two
    /// nodes of the same [`code`](VKind::code) *except* their parents
    /// (hashed separately by the interner, which knows each child's
    /// canonical identity). With `values = false` only the *structure*
    /// is hashed (op codes and shapes, not scalar constants, seeds or
    /// host-operand contents): the plan cache keys a loop body's shape,
    /// which must stay stable across iterations even though the small
    /// host operands change every iteration.
    pub fn hash_params<H: Hasher>(&self, h: &mut H, values: bool) {
        self.code().hash(h);
        match self {
            VKind::Fill(s) => hash_scalar(s, h, values),
            VKind::Seq { start, step } => {
                if values {
                    start.to_bits().hash(h);
                    step.to_bits().hash(h);
                }
            }
            VKind::RandU { seed, lo, hi } => {
                if values {
                    seed.hash(h);
                    lo.to_bits().hash(h);
                    hi.to_bits().hash(h);
                }
            }
            VKind::RandN { seed, mean, sd } => {
                if values {
                    seed.hash(h);
                    mean.to_bits().hash(h);
                    sd.to_bits().hash(h);
                }
            }
            VKind::Sapply { op, .. } => hash_unfn(op, h),
            VKind::Mapply { op, .. } => (*op as u8).hash(h),
            VKind::MapplyScalar {
                s,
                op,
                scalar_right,
                ..
            } => {
                hash_scalar(s, h, values);
                (*op as u8).hash(h);
                scalar_right.hash(h);
            }
            VKind::MapplyRow { w, op, .. } => {
                hash_host(w, h, values);
                (*op as u8).hash(h);
            }
            VKind::MapplyCol { op, .. } => (*op as u8).hash(h),
            VKind::RowAgg { op, na, .. } => {
                (*op as u8).hash(h);
                na.code().hash(h);
            }
            VKind::RowArgExtreme { max, .. } => max.hash(h),
            VKind::InnerSmall { b, f1, f2, .. } => {
                hash_host(b, h, values);
                (*f1 as u8).hash(h);
                (*f2 as u8).hash(h);
            }
            // The sparse operand is a *source* (not in `parents()`): its
            // Arc identity stands in for its contents, exactly like a
            // dense leaf. The right operand may be as long as the DAG's
            // long dimension, so it is identified by Arc pointer too —
            // conservative (a content-equal clone will not hash-cons),
            // never wrong.
            VKind::Spmm { a, b } => {
                if values {
                    a.data_ptr().hash(h);
                    (Arc::as_ptr(b) as usize).hash(h);
                }
            }
            VKind::Cast { to, .. } => (*to as u8).hash(h),
            VKind::ColBind(ms) => ms.len().hash(h),
            VKind::SelectCol { col, .. } => col.hash(h),
        }
    }

    /// Clone this node kind with its parents replaced by `ps`, which must
    /// be in [`parents()`](VKind::parents) order — the planner's rewrite
    /// step after hash-consing maps children onto canonical nodes.
    pub fn with_parents(&self, ps: &[Matrix]) -> VKind {
        debug_assert_eq!(ps.len(), self.parents().len());
        match self {
            VKind::Fill(s) => VKind::Fill(*s),
            VKind::Seq { start, step } => VKind::Seq {
                start: *start,
                step: *step,
            },
            VKind::RandU { seed, lo, hi } => VKind::RandU {
                seed: *seed,
                lo: *lo,
                hi: *hi,
            },
            VKind::RandN { seed, mean, sd } => VKind::RandN {
                seed: *seed,
                mean: *mean,
                sd: *sd,
            },
            VKind::Sapply { op, .. } => VKind::Sapply {
                a: ps[0].clone(),
                op: op.clone(),
            },
            VKind::Mapply { op, .. } => VKind::Mapply {
                a: ps[0].clone(),
                b: ps[1].clone(),
                op: *op,
            },
            VKind::MapplyScalar {
                s, op, scalar_right, ..
            } => VKind::MapplyScalar {
                a: ps[0].clone(),
                s: *s,
                op: *op,
                scalar_right: *scalar_right,
            },
            VKind::MapplyRow { w, op, .. } => VKind::MapplyRow {
                a: ps[0].clone(),
                w: w.clone(),
                op: *op,
            },
            VKind::MapplyCol { op, .. } => VKind::MapplyCol {
                a: ps[0].clone(),
                v: ps[1].clone(),
                op: *op,
            },
            VKind::RowAgg { op, na, .. } => VKind::RowAgg {
                a: ps[0].clone(),
                op: *op,
                na: *na,
            },
            VKind::RowArgExtreme { max, .. } => VKind::RowArgExtreme {
                a: ps[0].clone(),
                max: *max,
            },
            VKind::InnerSmall { b, f1, f2, .. } => VKind::InnerSmall {
                a: ps[0].clone(),
                b: b.clone(),
                f1: *f1,
                f2: *f2,
            },
            VKind::Spmm { a, b } => VKind::Spmm {
                a: a.clone(),
                b: Arc::clone(b),
            },
            VKind::Cast { to, .. } => VKind::Cast {
                a: ps[0].clone(),
                to: *to,
            },
            VKind::ColBind(_) => VKind::ColBind(ps.to_vec()),
            VKind::SelectCol { col, .. } => VKind::SelectCol {
                a: ps[0].clone(),
                col: *col,
            },
        }
    }
}

fn hash_scalar<H: Hasher>(s: &Scalar, h: &mut H, values: bool) {
    (s.dtype() as u8).hash(h);
    if !values {
        return;
    }
    match *s {
        Scalar::Bool(b) => b.hash(h),
        Scalar::I32(v) => v.hash(h),
        Scalar::I64(v) => v.hash(h),
        Scalar::F32(v) => v.to_bits().hash(h),
        Scalar::F64(v) => v.to_bits().hash(h),
    }
}

fn hash_unfn<H: Hasher>(f: &UnFn, h: &mut H) {
    match f {
        UnFn::Builtin(op) => {
            0u8.hash(h);
            (*op as u8).hash(h);
        }
        // a registered VUDF's name is its identity in the registry
        UnFn::Custom(c) => {
            1u8.hash(h);
            c.name().hash(h);
        }
    }
}

/// Small host operands (`mapply.row` weights, `inner.prod` right sides)
/// hash by content: iterative algorithms rebuild them with fresh
/// allocations every iteration, and content equality is exactly what
/// makes two such nodes interchangeable.
fn hash_host<H: Hasher>(m: &HostMat, h: &mut H, values: bool) {
    m.nrow.hash(h);
    m.ncol.hash(h);
    (m.buf.dtype() as u8).hash(h);
    if values {
        m.buf.to_bytes().hash(h);
    }
}

/// Sink kinds: DAG-terminating aggregations (different long dimension).
pub enum SinkKind {
    /// `fm.agg`: whole-matrix reduction to one scalar. The [`NaMode`]
    /// selects NA handling (`Off` = legacy NA-oblivious kernels).
    AggFull(AggOp, NaMode),
    /// `fm.agg.col` on a tall matrix: per-column reduction -> 1×ncol.
    AggCol(AggOp, NaMode),
    /// `fm.groupby.row`: rows grouped by an n×1 i32 label matrix (values in
    /// `0..k`), reduced per group -> k×ncol. Labels may be virtual and are
    /// evaluated in the same fused pass (k-means' one-pass update).
    GroupByRow { labels: Matrix, k: usize, op: AggOp },
    /// Wide×tall generalized inner product `fm.inner.prod(t(A), B, f1,f2)`
    /// -> ncol(A)×ncol(B). Both operands share the long dimension. The
    /// Gramian (t(X)·X) and GMM sufficient statistics use this.
    InnerWideTall { right: Matrix, f1: BinOp, f2: AggOp },
}

impl SinkKind {
    /// Stable discriminant for structural sink identity.
    pub fn code(&self) -> u8 {
        match self {
            SinkKind::AggFull(..) => 0,
            SinkKind::AggCol(..) => 1,
            SinkKind::GroupByRow { .. } => 2,
            SinkKind::InnerWideTall { .. } => 3,
        }
    }

    /// DAG-edge matrices embedded in the sink kind (the labels of a
    /// group-by, the right operand of a wide×tall inner product) — these
    /// participate in hash-consing exactly like node parents.
    pub fn parents(&self) -> Vec<&Matrix> {
        match self {
            SinkKind::AggFull(..) | SinkKind::AggCol(..) => vec![],
            SinkKind::GroupByRow { labels, .. } => vec![labels],
            SinkKind::InnerWideTall { right, .. } => vec![right],
        }
    }

    /// Hash the sink-local parameters (ops, group count) — embedded
    /// matrices are hashed by the interner via [`parents()`](Self::parents).
    pub fn hash_params<H: Hasher>(&self, h: &mut H) {
        self.code().hash(h);
        match self {
            SinkKind::AggFull(op, na) | SinkKind::AggCol(op, na) => {
                (*op as u8).hash(h);
                na.code().hash(h);
            }
            SinkKind::GroupByRow { k, op, .. } => {
                k.hash(h);
                (*op as u8).hash(h);
            }
            SinkKind::InnerWideTall { f1, f2, .. } => {
                (*f1 as u8).hash(h);
                (*f2 as u8).hash(h);
            }
        }
    }

    /// Clone with the embedded matrices replaced, in
    /// [`parents()`](Self::parents) order.
    pub fn with_parents(&self, ps: &[Matrix]) -> SinkKind {
        debug_assert_eq!(ps.len(), self.parents().len());
        match self {
            SinkKind::AggFull(op, na) => SinkKind::AggFull(*op, *na),
            SinkKind::AggCol(op, na) => SinkKind::AggCol(*op, *na),
            SinkKind::GroupByRow { k, op, .. } => SinkKind::GroupByRow {
                labels: ps[0].clone(),
                k: *k,
                op: *op,
            },
            SinkKind::InnerWideTall { f1, f2, .. } => SinkKind::InnerWideTall {
                right: ps[0].clone(),
                f1: *f1,
                f2: *f2,
            },
        }
    }
}

/// A sink: source matrix (virtual or dense) + terminal aggregation.
pub struct SinkSpec {
    pub source: Matrix,
    pub kind: SinkKind,
}

/// Result of materializing one sink.
#[derive(Clone, Debug)]
pub enum SinkResult {
    Scalar(Scalar),
    Mat(HostMat),
}

impl SinkResult {
    pub fn scalar(&self) -> Scalar {
        match self {
            SinkResult::Scalar(s) => *s,
            SinkResult::Mat(_) => panic!("sink produced a matrix, not a scalar"),
        }
    }

    pub fn mat(&self) -> &HostMat {
        match self {
            SinkResult::Mat(m) => m,
            SinkResult::Scalar(_) => panic!("sink produced a scalar, not a matrix"),
        }
    }
}

/// Depth-first collection of the unique nodes reachable from `roots`, in
/// topological (parents-before-children) order. Nodes are deduplicated by
/// `Arc` pointer identity, so diamonds evaluate once (§III-E: "a matrix
/// node can be used by multiple computation nodes").
pub fn topo_order(roots: &[Matrix]) -> Vec<Matrix> {
    let mut seen: HashMap<usize, ()> = HashMap::new();
    let mut order = Vec::new();
    fn visit(m: &Matrix, seen: &mut HashMap<usize, ()>, order: &mut Vec<Matrix>) {
        let key = m.data_ptr();
        if seen.contains_key(&key) {
            return;
        }
        seen.insert(key, ());
        if let MatrixData::Virtual(v) = &*m.data {
            for p in v.kind.parents() {
                visit(p, seen, order);
            }
        }
        order.push(m.canonical());
    }
    for r in roots {
        visit(r, &mut seen, &mut order);
    }
    order
}

/// Validate that every node reachable from `roots` shares one long
/// dimension (§III-E requires it of a DAG).
pub fn validate_long_dim(roots: &[Matrix]) -> Result<u64> {
    let order = topo_order(roots);
    let mut long: Option<u64> = None;
    for m in &order {
        let n = m.data.nrow();
        match long {
            None => long = Some(n),
            Some(l) if l != n => {
                return Err(FmError::Shape(format!(
                    "DAG long-dimension mismatch: {l} vs {n}"
                )))
            }
            _ => {}
        }
    }
    long.ok_or_else(|| FmError::Shape("empty DAG".into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(nrow: u64, ncol: u64) -> Matrix {
        Matrix::new(MatrixData::Virtual(VNode {
            nrow,
            ncol,
            dtype: DType::F64,
            kind: VKind::Fill(Scalar::F64(1.0)),
        }))
    }

    fn mapply(a: &Matrix, b: &Matrix) -> Matrix {
        Matrix::new(MatrixData::Virtual(VNode {
            nrow: a.nrow(),
            ncol: a.ncol(),
            dtype: DType::F64,
            kind: VKind::Mapply {
                a: a.clone(),
                b: b.clone(),
                op: BinOp::Add,
            },
        }))
    }

    #[test]
    fn topo_dedups_diamond() {
        let x = fill(100, 2);
        let a = mapply(&x, &x); // diamond on x
        let b = mapply(&a, &x);
        let order = topo_order(&[b.clone()]);
        assert_eq!(order.len(), 3); // x, a, b — x once
        assert_eq!(order[0].data_ptr(), x.data_ptr());
        assert_eq!(order[2].data_ptr(), b.data_ptr());
    }

    #[test]
    fn long_dim_validated() {
        let x = fill(100, 2);
        let y = fill(100, 2);
        assert_eq!(validate_long_dim(&[mapply(&x, &y)]).unwrap(), 100);
        let z = fill(50, 2);
        // building the bad node directly — validation must catch it
        let bad = mapply(&x, &z);
        assert!(validate_long_dim(&[bad]).is_err());
    }

    #[test]
    fn parents_enumerated() {
        let x = fill(10, 1);
        let v = VNode {
            nrow: 10,
            ncol: 1,
            dtype: DType::F64,
            kind: VKind::Sapply {
                a: x.clone(),
                op: UnFn::Builtin(UnOp::Abs),
            },
        };
        assert_eq!(v.kind.parents().len(), 1);
    }
}
