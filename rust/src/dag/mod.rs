//! Lazy evaluation: virtual matrices and the operation DAG (paper §III-E).
//!
//! Every GenOp returns a *virtual matrix* — a [`VNode`] recording the
//! computation and `Arc` references to its parent matrices. A chain of
//! GenOps therefore builds a DAG bottom-up for free; nothing executes until
//! [`crate::exec`] materializes target matrices / sinks, at which point the
//! whole DAG runs as **one** partition-streaming pass (operation fusion).
//!
//! Two node classes mirror the paper's:
//! * *elementwise* nodes keep the DAG's shared long dimension (`fm.sapply`,
//!   `fm.mapply*`, per-row reductions on tall matrices, inner products with
//!   a small right operand, casts, cbind) and can feed further nodes;
//! * *sink* nodes ([`SinkSpec`]) end a DAG (`fm.agg`, `fm.agg.col`,
//!   `fm.groupby.row`, wide×tall inner products); their outputs are small
//!   host matrices produced by per-thread partial aggregation + merge
//!   (§III-F).

use std::collections::HashMap;
use std::sync::Arc;

use crate::dtype::{DType, Scalar};
use crate::error::{FmError, Result};
use crate::matrix::{HostMat, Matrix, MatrixData};
use crate::vudf::{AggOp, BinOp, CustomVudf, UnOp};

/// Unary op reference: built-in (enum fast path) or registered custom VUDF.
#[derive(Clone)]
pub enum UnFn {
    Builtin(UnOp),
    Custom(Arc<dyn CustomVudf>),
}

impl UnFn {
    pub fn out_dtype(&self, input: DType) -> DType {
        match self {
            UnFn::Builtin(op) => op.out_dtype(input),
            UnFn::Custom(c) => c.out_dtype(input),
        }
    }
}

impl std::fmt::Debug for UnFn {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            UnFn::Builtin(op) => write!(f, "{op:?}"),
            UnFn::Custom(c) => write!(f, "custom:{}", c.name()),
        }
    }
}

/// A virtual matrix: shape + recorded computation.
pub struct VNode {
    /// Canonical rows — the DAG long dimension.
    pub nrow: u64,
    pub ncol: u64,
    pub dtype: DType,
    pub kind: VKind,
}

/// The recorded computation of a virtual matrix.
pub enum VKind {
    /// Every element equals a constant (e.g. `fm.rep.int`).
    Fill(Scalar),
    /// One-column sequence by global row index: `start + step*row`
    /// (`fm.seq.int`).
    Seq { start: f64, step: f64 },
    /// Counter-based uniform randomness: element (r,j) derives from
    /// `splitmix64_at(seed, r*ncol + j)` — partition-order independent
    /// (`fm.runif.matrix`).
    RandU { seed: u64, lo: f64, hi: f64 },
    /// Counter-based normal randomness via Box-Muller
    /// (`fm.rnorm.matrix`).
    RandN { seed: u64, mean: f64, sd: f64 },
    /// `fm.sapply`.
    Sapply { a: Matrix, op: UnFn },
    /// `fm.mapply` (elementwise, both operands share the long dim).
    Mapply { a: Matrix, b: Matrix, op: BinOp },
    /// `fm.mapply` against a scalar (vector ⊕ scalar forms).
    MapplyScalar {
        a: Matrix,
        s: Scalar,
        op: BinOp,
        /// true: `f(a, s)` (bVUDF2); false: `f(s, a)` (bVUDF3).
        scalar_right: bool,
    },
    /// `fm.mapply.row`: combine each row with a small host vector
    /// (len = ncol).
    MapplyRow { a: Matrix, w: HostMat, op: BinOp },
    /// `fm.mapply.col`: combine each column with an n×1 matrix sharing the
    /// long dimension (itself possibly virtual — this is what lets whole
    /// normalization pipelines fuse).
    MapplyCol { a: Matrix, v: Matrix, op: BinOp },
    /// `fm.agg.row` on a tall matrix: per-row reduction, n×1 output —
    /// stays in the DAG (paper §III-E "first type").
    RowAgg { a: Matrix, op: AggOp },
    /// Per-row index of the extreme value (1-based like R's which.min);
    /// i32 output. Backs `fm.agg.row(which.min/which.max)`.
    RowArgExtreme { a: Matrix, max: bool },
    /// Generalized inner product with a *small* right operand
    /// (tall n×p ⊗ small p×q -> tall n×q): `fm.inner.prod(A, B, f1, f2)`.
    InnerSmall {
        a: Matrix,
        b: HostMat,
        f1: BinOp,
        f2: AggOp,
    },
    /// Streaming sparse × small-dense multiply (`fm.multiply` on a sparse
    /// left operand): CSR row-partitions of `a` (n×m) stream against the
    /// in-memory right operand `b` (m×q) -> tall n×q dense. `a` is a
    /// *source* like a dense input, not a register-producing node — the
    /// strip evaluator decodes its CSR bytes directly — so `parents()`
    /// does not list it. `b` sits behind an `Arc`: compiling the node
    /// into a pass must not copy the (potentially n-element) operand.
    Spmm { a: Matrix, b: Arc<HostMat> },
    /// Lazy element-type cast.
    Cast { a: Matrix, to: DType },
    /// Column concatenation of same-long-dim nodes (`fm.cbind` within a
    /// DAG).
    ColBind(Vec<Matrix>),
    /// Select one column of a node as an n×1 matrix (`A[, j]`).
    SelectCol { a: Matrix, col: u64 },
}

impl VKind {
    /// Parent matrices (DAG edges).
    pub fn parents(&self) -> Vec<&Matrix> {
        match self {
            VKind::Fill(_)
            | VKind::Seq { .. }
            | VKind::RandU { .. }
            | VKind::RandN { .. }
            | VKind::Spmm { .. } => {
                vec![]
            }
            VKind::Sapply { a, .. }
            | VKind::MapplyScalar { a, .. }
            | VKind::MapplyRow { a, .. }
            | VKind::RowAgg { a, .. }
            | VKind::RowArgExtreme { a, .. }
            | VKind::InnerSmall { a, .. }
            | VKind::Cast { a, .. }
            | VKind::SelectCol { a, .. } => vec![a],
            VKind::Mapply { a, b, .. } => vec![a, b],
            VKind::MapplyCol { a, v, .. } => vec![a, v],
            VKind::ColBind(ms) => ms.iter().collect(),
        }
    }
}

/// Sink kinds: DAG-terminating aggregations (different long dimension).
pub enum SinkKind {
    /// `fm.agg`: whole-matrix reduction to one scalar.
    AggFull(AggOp),
    /// `fm.agg.col` on a tall matrix: per-column reduction -> 1×ncol.
    AggCol(AggOp),
    /// `fm.groupby.row`: rows grouped by an n×1 i32 label matrix (values in
    /// `0..k`), reduced per group -> k×ncol. Labels may be virtual and are
    /// evaluated in the same fused pass (k-means' one-pass update).
    GroupByRow { labels: Matrix, k: usize, op: AggOp },
    /// Wide×tall generalized inner product `fm.inner.prod(t(A), B, f1,f2)`
    /// -> ncol(A)×ncol(B). Both operands share the long dimension. The
    /// Gramian (t(X)·X) and GMM sufficient statistics use this.
    InnerWideTall { right: Matrix, f1: BinOp, f2: AggOp },
}

/// A sink: source matrix (virtual or dense) + terminal aggregation.
pub struct SinkSpec {
    pub source: Matrix,
    pub kind: SinkKind,
}

/// Result of materializing one sink.
#[derive(Clone, Debug)]
pub enum SinkResult {
    Scalar(Scalar),
    Mat(HostMat),
}

impl SinkResult {
    pub fn scalar(&self) -> Scalar {
        match self {
            SinkResult::Scalar(s) => *s,
            SinkResult::Mat(_) => panic!("sink produced a matrix, not a scalar"),
        }
    }

    pub fn mat(&self) -> &HostMat {
        match self {
            SinkResult::Mat(m) => m,
            SinkResult::Scalar(_) => panic!("sink produced a scalar, not a matrix"),
        }
    }
}

/// Depth-first collection of the unique nodes reachable from `roots`, in
/// topological (parents-before-children) order. Nodes are deduplicated by
/// `Arc` pointer identity, so diamonds evaluate once (§III-E: "a matrix
/// node can be used by multiple computation nodes").
pub fn topo_order(roots: &[Matrix]) -> Vec<Matrix> {
    let mut seen: HashMap<usize, ()> = HashMap::new();
    let mut order = Vec::new();
    fn visit(m: &Matrix, seen: &mut HashMap<usize, ()>, order: &mut Vec<Matrix>) {
        let key = m.data_ptr();
        if seen.contains_key(&key) {
            return;
        }
        seen.insert(key, ());
        if let MatrixData::Virtual(v) = &*m.data {
            for p in v.kind.parents() {
                visit(p, seen, order);
            }
        }
        order.push(m.canonical());
    }
    for r in roots {
        visit(r, &mut seen, &mut order);
    }
    order
}

/// Validate that every node reachable from `roots` shares one long
/// dimension (§III-E requires it of a DAG).
pub fn validate_long_dim(roots: &[Matrix]) -> Result<u64> {
    let order = topo_order(roots);
    let mut long: Option<u64> = None;
    for m in &order {
        let n = m.data.nrow();
        match long {
            None => long = Some(n),
            Some(l) if l != n => {
                return Err(FmError::Shape(format!(
                    "DAG long-dimension mismatch: {l} vs {n}"
                )))
            }
            _ => {}
        }
    }
    long.ok_or_else(|| FmError::Shape("empty DAG".into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(nrow: u64, ncol: u64) -> Matrix {
        Matrix::new(MatrixData::Virtual(VNode {
            nrow,
            ncol,
            dtype: DType::F64,
            kind: VKind::Fill(Scalar::F64(1.0)),
        }))
    }

    fn mapply(a: &Matrix, b: &Matrix) -> Matrix {
        Matrix::new(MatrixData::Virtual(VNode {
            nrow: a.nrow(),
            ncol: a.ncol(),
            dtype: DType::F64,
            kind: VKind::Mapply {
                a: a.clone(),
                b: b.clone(),
                op: BinOp::Add,
            },
        }))
    }

    #[test]
    fn topo_dedups_diamond() {
        let x = fill(100, 2);
        let a = mapply(&x, &x); // diamond on x
        let b = mapply(&a, &x);
        let order = topo_order(&[b.clone()]);
        assert_eq!(order.len(), 3); // x, a, b — x once
        assert_eq!(order[0].data_ptr(), x.data_ptr());
        assert_eq!(order[2].data_ptr(), b.data_ptr());
    }

    #[test]
    fn long_dim_validated() {
        let x = fill(100, 2);
        let y = fill(100, 2);
        assert_eq!(validate_long_dim(&[mapply(&x, &y)]).unwrap(), 100);
        let z = fill(50, 2);
        // building the bad node directly — validation must catch it
        let bad = mapply(&x, &z);
        assert!(validate_long_dim(&[bad]).is_err());
    }

    #[test]
    fn parents_enumerated() {
        let x = fill(10, 1);
        let v = VNode {
            nrow: 10,
            ncol: 1,
            dtype: DType::F64,
            kind: VKind::Sapply {
                a: x.clone(),
                op: UnFn::Builtin(UnOp::Abs),
            },
        };
        assert_eq!(v.kind.parents().len(), 1);
    }
}
