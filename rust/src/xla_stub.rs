#![allow(dead_code)]
//! Build-anywhere stub for the native `xla` crate (xla-rs).
//!
//! The real PJRT bindings need the XLA C library at link time, which this
//! repo does not vendor. `src/runtime/mod.rs` and `src/error.rs` import
//! this module under the name `xla` (`use crate::xla_stub as xla;`), so
//! the whole AOT dispatch path type-checks and the engine degrades
//! gracefully at runtime: [`PjRtClient::cpu`] reports that the backend is
//! unavailable, `XlaService` fails every request with that message, and
//! the executor falls back to the native GenOp path (exactly the paper's
//! behaviour without BLAS).
//!
//! To enable real XLA dispatch, add the `xla` crate (built from source
//! against your XLA installation) to `Cargo.toml` and delete the two
//! `use crate::xla_stub as xla;` lines plus this file — the API surface
//! below mirrors the subset of xla-rs the runtime uses.

use std::fmt;

/// Error type mirroring `xla::Error`.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "XLA backend not linked (stub build; see src/xla_stub.rs)".into(),
    ))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

pub struct ArrayShape;

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &[]
    }

    pub fn ty(&self) -> ElementType {
        ElementType::F64
    }
}

/// Element types the runtime dispatches on (plus a catch-all so matches
/// over the real crate's wider enum keep their `other` arm).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F64,
    F32,
    S32,
    S64,
    Pred,
    U8,
}
