//! Experiment harness: one function per paper figure/table (DESIGN.md
//! experiment index). The CLI (`flashmatrix bench <fig>`) and the bench
//! binaries call these; EXPERIMENTS.md records their output.
//!
//! Workloads are scaled for the testbed via [`Scale`]; the *shape* of each
//! figure (who wins, by what factor, where curves cross) is the
//! reproduction target, not the paper's absolute numbers (48-core NUMA +
//! 24-SSD array vs this machine — DESIGN.md §Substitutions).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use crate::algs;
use crate::baselines::reference::{self, RefMat};
use crate::config::{EngineConfig, StorageKind, ThrottleConfig};
use crate::error::Result;
use crate::fmr::{Engine, FmMatrix};
use crate::util::bench::Table;
use crate::util::json::{obj, Json};

// ---------------------------------------------------------------------------
// Machine-readable bench reports (the CI perf trajectory)
// ---------------------------------------------------------------------------

/// Version of the `BENCH_<name>.json` schema below. Bump when the shape
/// changes; the CI gate (`python/bench_gate.py`) refuses versions it does
/// not know.
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// One bench binary's machine-readable report, written as
/// `BENCH_<name>.json`. **This struct is the schema** — every bench and
/// the CI regression gate share it:
///
/// ```json
/// {
///   "schema_version": 1,
///   "bench": "writeback",
///   "tables": [            // one entry per printed Table, same order
///     { "title": "...",
///       "rows": [ { "label": "write-back", "value": 0.41, "unit": "s",
///                   "wb_enqueued": 24.0, ... } ] }   // extras inline
///   ],
///   "checks": [            // the bench's own pass/fail acceptance checks
///     { "name": "writeback-strictly-faster", "pass": true }
///   ]
/// }
/// ```
///
/// Wall-times live in rows with `"unit": "s"`; engine counters ride as
/// extra numeric fields of the same row. The committed
/// `rust/benches/baseline.json` references rows by `label` and lists the
/// counter fields that must stay present — a renamed counter fails CI
/// just like a wall-time regression.
pub struct BenchReport {
    name: String,
    tables: Vec<Json>,
    checks: Vec<(String, bool)>,
}

impl BenchReport {
    pub fn new(name: impl Into<String>) -> BenchReport {
        BenchReport {
            name: name.into(),
            tables: Vec::new(),
            checks: Vec::new(),
        }
    }

    /// Record one results table (call in print order).
    pub fn add_table(&mut self, t: &Table) {
        self.tables.push(t.to_json());
    }

    /// Record one named acceptance check (the PASS/FAIL lines the bench
    /// prints — machine-readable here so CI can gate on them).
    pub fn add_check(&mut self, name: impl Into<String>, pass: bool) {
        self.checks.push((name.into(), pass));
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("schema_version", Json::from(BENCH_SCHEMA_VERSION)),
            ("bench", Json::from(self.name.clone())),
            ("tables", Json::Arr(self.tables.clone())),
            (
                "checks",
                Json::Arr(
                    self.checks
                        .iter()
                        .map(|(n, p)| {
                            obj(vec![("name", Json::from(n.clone())), ("pass", Json::Bool(*p))])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write `BENCH_<name>.json` under `dir` (created if missing) and
    /// return the path. Benches route `dir` from their `--json-dir` flag.
    pub fn write(&self, dir: &Path) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_string())?;
        println!("wrote {}", path.display());
        Ok(path)
    }
}

/// Workload scale knobs (defaults sized for a 2-core dev box).
#[derive(Clone, Debug)]
pub struct Scale {
    /// Rows of the MixGaussian matrix (paper: 1B).
    pub n: u64,
    /// Rows for the single-thread Fig 7 runs (paper: 65M).
    pub n_small: u64,
    /// Iterations for k-means / GMM.
    pub iters: usize,
    /// Threads for the parallel figures.
    pub threads: usize,
    /// Simulated SSD bandwidth (bytes/s) for EM runs.
    pub ssd_bps: u64,
    /// Artifacts directory.
    pub artifacts_dir: String,
    /// Data directory for EM files.
    pub data_dir: String,
    /// Enable the XLA fast path where artifacts match.
    pub xla: bool,
}

impl Default for Scale {
    fn default() -> Scale {
        Scale {
            n: 200_000,
            n_small: 100_000,
            iters: 3,
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(2),
            ssd_bps: 1 << 30, // 1 GiB/s deterministic budget
            artifacts_dir: "artifacts".into(),
            data_dir: "data".into(),
            xla: true,
        }
    }
}

/// Engine execution modes compared across the figures.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Mode {
    FmIm,
    FmEm,
    MllibLike,
}

impl Mode {
    pub fn label(self) -> &'static str {
        match self {
            Mode::FmIm => "FM-IM",
            Mode::FmEm => "FM-EM",
            Mode::MllibLike => "MLlib-like",
        }
    }
}

/// Configuration for a mode at a given thread count ([`engine_for`]
/// without building the engine — for callers that tweak knobs first).
pub fn config_for(s: &Scale, mode: Mode, threads: usize) -> EngineConfig {
    let mut cfg = match mode {
        Mode::FmIm => EngineConfig::fm_im(),
        Mode::FmEm => EngineConfig {
            storage: StorageKind::External,
            throttle: Some(ThrottleConfig {
                read_bytes_per_sec: s.ssd_bps,
                write_bytes_per_sec: s.ssd_bps,
            }),
            // The figure harness runs at testbed scale, where datasets are
            // far smaller than the paper's (1B rows): a default-sized
            // partition cache would absorb them whole and zero out the EM
            // I/O these figures exist to measure (Table IV counts data
            // passes from io_read_bytes). The cache has its own ablation
            // in benches/cache_ablation.rs.
            em_cache_bytes: 0,
            prefetch_depth: 0,
            ..EngineConfig::fm_im()
        },
        Mode::MllibLike => EngineConfig::mllib_like(),
    };
    cfg.threads = threads;
    cfg.data_dir = s.data_dir.clone().into();
    cfg.artifacts_dir = s.artifacts_dir.clone().into();
    cfg.xla_dispatch = s.xla && mode != Mode::MllibLike;
    cfg
}

/// Build an engine for a mode at a given thread count.
pub fn engine_for(s: &Scale, mode: Mode, threads: usize) -> Result<Arc<Engine>> {
    Engine::new(config_for(s, mode, threads))
}

/// The five evaluation algorithms.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Alg {
    Summary,
    Correlation,
    Svd,
    Kmeans,
    Gmm,
}

pub const ALL_ALGS: [Alg; 5] = [
    Alg::Summary,
    Alg::Correlation,
    Alg::Svd,
    Alg::Kmeans,
    Alg::Gmm,
];

impl Alg {
    pub fn label(self) -> &'static str {
        match self {
            Alg::Summary => "summary",
            Alg::Correlation => "correlation",
            Alg::Svd => "svd",
            Alg::Kmeans => "kmeans",
            Alg::Gmm => "gmm",
        }
    }
}

/// Run one algorithm on a prepared matrix; returns wall seconds.
pub fn run_alg(x: &FmMatrix, alg: Alg, k: usize, iters: usize) -> Result<f64> {
    let t0 = Instant::now();
    match alg {
        Alg::Summary => {
            algs::summary(x)?;
        }
        Alg::Correlation => {
            algs::correlation(x)?;
        }
        Alg::Svd => {
            algs::svd(x, 10.min(x.ncol() as usize))?;
        }
        Alg::Kmeans => {
            algs::kmeans(x, k, iters, 1)?;
        }
        Alg::Gmm => {
            algs::gmm(x, k, iters, 1)?;
        }
    }
    Ok(t0.elapsed().as_secs_f64())
}

fn dataset(eng: &Arc<Engine>, n: u64, p: u64) -> Result<FmMatrix> {
    Ok(crate::datasets::mix_gaussian(eng, n, p, 10, 6.0, 42, None)?.0)
}

// ---------------------------------------------------------------------------
// Figures
// ---------------------------------------------------------------------------

/// Fig 6(a): runtime of the five algorithms — FM-IM vs FM-EM vs the eager
/// MLlib-like baseline, MixGaussian n×32, k = 10. The eager baseline runs
/// a 10x smaller input (it is drastically slower) and its time is
/// normalized back to `n` rows.
pub fn fig6a(s: &Scale) -> Result<Table> {
    let mut t = Table::new(format!(
        "Fig 6(a) runtime [s], MixGaussian {}x32, k=10, {} threads",
        s.n, s.threads
    ));
    for alg in ALL_ALGS {
        for mode in [Mode::FmIm, Mode::FmEm, Mode::MllibLike] {
            let n = if mode == Mode::MllibLike { s.n / 10 } else { s.n };
            let eng = engine_for(s, mode, s.threads)?;
            let x = dataset(&eng, n, 32)?;
            let secs = run_alg(&x, alg, 10, s.iters)?;
            let scaled = secs * (s.n as f64 / n as f64);
            t.add(format!("{} {}", alg.label(), mode.label()), scaled, "s");
        }
    }
    Ok(t)
}

/// Fig 6(b): peak tracked memory for the same runs.
pub fn fig6b(s: &Scale) -> Result<Table> {
    let mut t = Table::new(format!(
        "Fig 6(b) peak memory [GB], MixGaussian {}x32, k=10",
        s.n
    ));
    for alg in ALL_ALGS {
        for mode in [Mode::FmIm, Mode::FmEm, Mode::MllibLike] {
            let n = if mode == Mode::MllibLike { s.n / 10 } else { s.n };
            let eng = engine_for(s, mode, s.threads)?;
            let x = dataset(&eng, n, 32)?;
            eng.metrics.reset();
            // account the resident input for IM modes (the dataset chunks
            // were acquired before the reset)
            if mode != Mode::FmEm {
                eng.metrics.mem_acquire(n * 32 * 8);
            }
            run_alg(&x, alg, 10, s.iters)?;
            let peak = eng.metrics.snapshot().mem_peak as f64 / 1e9;
            let scaled = peak * (s.n as f64 / n as f64);
            t.add(format!("{} {}", alg.label(), mode.label()), scaled, "GB");
        }
    }
    Ok(t)
}

/// Fig 7: single-thread FM-IM / FM-EM vs the R-style reference
/// implementations (correlation, SVD, k-means, GMM) on the
/// spectral (Friendster-like) matrix.
pub fn fig7(s: &Scale) -> Result<Table> {
    let mut t = Table::new(format!(
        "Fig 7 single-thread runtime [s], spectral {}x32",
        s.n_small
    ));
    let algs4 = [Alg::Correlation, Alg::Svd, Alg::Kmeans, Alg::Gmm];
    for alg in algs4 {
        for mode in [Mode::FmIm, Mode::FmEm] {
            let eng = engine_for(s, mode, 1)?;
            let x = crate::datasets::spectral_like(&eng, s.n_small, 32, 42, None)?;
            // Dataset creation queues simulated SSD writes; drain them so
            // the timed region measures the algorithm, not leftover bursts.
            eng.ssd.drain_bursts();
            let secs = run_alg(&x, alg, 10, s.iters)?;
            t.add(format!("{} {}", alg.label(), mode.label()), secs, "s");
        }
        // R-style reference (single thread by construction)
        let eng = engine_for(s, Mode::FmIm, 1)?;
        let x = crate::datasets::spectral_like(&eng, s.n_small, 32, 42, None)?;
        let r = RefMat::from_fm(&x)?;
        let init = algs::kmeans::init_centroids(&x, 10, 1)?;
        eng.ssd.drain_bursts();
        let t0 = Instant::now();
        match alg {
            Alg::Correlation => {
                reference::correlation_ref(&r);
            }
            Alg::Svd => {
                reference::svd_ref(&r, 10)?;
            }
            Alg::Kmeans => {
                reference::kmeans_ref(&r, &init, s.iters);
            }
            Alg::Gmm => {
                reference::gmm_ref(&r, &init, s.iters)?;
            }
            Alg::Summary => unreachable!(),
        }
        t.add(
            format!("{} R-ref", alg.label()),
            t0.elapsed().as_secs_f64(),
            "s",
        );
    }
    Ok(t)
}

/// Fig 8: speedup vs thread count, IM and EM (native GenOp path so the
/// engine's own parallelism is what is measured).
pub fn fig8(s: &Scale, max_threads: usize) -> Result<Table> {
    let mut t = Table::new(format!("Fig 8 speedup vs threads, {}x32", s.n));
    let mut s2 = s.clone();
    s2.xla = false;
    for alg in ALL_ALGS {
        for mode in [Mode::FmIm, Mode::FmEm] {
            let mut base = None;
            for threads in 1..=max_threads {
                let eng = engine_for(&s2, mode, threads)?;
                let x = dataset(&eng, s2.n, 32)?;
                eng.ssd.drain_bursts();
                eng.metrics.reset();
                let secs = run_alg(&x, alg, 10, s2.iters)?;
                let m = eng.metrics.snapshot();
                let speedup = base.map(|b: f64| b / secs).unwrap_or(1.0);
                if base.is_none() {
                    base = Some(secs);
                }
                t.add_with(
                    format!("{} {} t={}", alg.label(), mode.label(), threads),
                    speedup,
                    "x",
                    // scheduler behaviour behind the scaling curve: range
                    // steals (load balance) and read-aheads (I/O overlap)
                    vec![
                        ("secs".into(), secs),
                        ("steals".into(), m.sched_steals as f64),
                        ("prefetches".into(), m.prefetch_issued as f64),
                    ],
                );
            }
        }
    }
    Ok(t)
}

/// Fig 9: EM performance relative to IM for summary/correlation/SVD as
/// the column count sweeps 8..512 (random matrices).
pub fn fig9(s: &Scale, ps: &[u64]) -> Result<Table> {
    let mut t = Table::new(format!(
        "Fig 9 EM relative perf (IM/EM time), random {} rows",
        s.n
    ));
    for alg in [Alg::Summary, Alg::Correlation, Alg::Svd] {
        for &p in ps {
            let t_im = {
                let eng = engine_for(s, Mode::FmIm, s.threads)?;
                let x = crate::datasets::uniform(&eng, s.n, p, -1.0, 1.0, 7, None)?;
                run_alg(&x, alg, 10, s.iters)?
            };
            let t_em = {
                let eng = engine_for(s, Mode::FmEm, s.threads)?;
                let x = crate::datasets::uniform(&eng, s.n, p, -1.0, 1.0, 7, None)?;
                run_alg(&x, alg, 10, s.iters)?
            };
            t.add_with(
                format!("{} p={}", alg.label(), p),
                t_im / t_em,
                "(EM/IM rel perf)",
                vec![("im_s".into(), t_im), ("em_s".into(), t_em)],
            );
        }
    }
    Ok(t)
}

/// Fig 10: EM relative performance for k-means/GMM as the cluster count
/// sweeps (spectral matrix, p = 32).
pub fn fig10(s: &Scale, ks: &[usize]) -> Result<Table> {
    let mut t = Table::new(format!(
        "Fig 10 EM relative perf (IM/EM time), spectral {}x32",
        s.n
    ));
    for alg in [Alg::Kmeans, Alg::Gmm] {
        for &k in ks {
            let t_im = {
                let eng = engine_for(s, Mode::FmIm, s.threads)?;
                let x = crate::datasets::spectral_like(&eng, s.n, 32, 42, None)?;
                run_alg(&x, alg, k, s.iters)?
            };
            let t_em = {
                let eng = engine_for(s, Mode::FmEm, s.threads)?;
                let x = crate::datasets::spectral_like(&eng, s.n, 32, 42, None)?;
                run_alg(&x, alg, k, s.iters)?
            };
            t.add_with(
                format!("{} k={}", alg.label(), k),
                t_im / t_em,
                "(EM/IM rel perf)",
                vec![("im_s".into(), t_im), ("em_s".into(), t_em)],
            );
        }
    }
    Ok(t)
}

/// Fig 11: cumulative memory-optimization ablation. Configurations, in
/// paper order: base (none) -> +mem-alloc (chunk recycling) -> +mem-fuse
/// -> +cache-fuse, plus this repo's `+strip-fusion` step (liveness-driven
/// register reuse, in-place kernels and peephole-fused VUDF chains in the
/// strip evaluator), the `+simd` step (explicit lane kernels and
/// register-blocked GEMM microkernels, `EngineConfig::simd_kernels`) and
/// the `+cross-pass` step (the [`crate::plan`] optimizer,
/// `EngineConfig::cross_pass_opt`).
/// Reported as speedup over base, on SSDs (EM) or in memory (IM); each
/// row carries the strip-allocation counters (`buf_allocs` / `buf_reuses`
/// / `inplace_ops` / `fused_chain_len`), the microkernel counters
/// (`simd_strips` / `simd_lanes` / `gemm_panels`) and the optimizer
/// counters (`passes` / `cse_hits` / `sinks_pruned` / `mat_decisions`).
pub fn fig11(s: &Scale, em: bool) -> Result<Table> {
    let mode = if em { Mode::FmEm } else { Mode::FmIm };
    let mut t = Table::new(format!(
        "Fig 11({}) memory-optimization ablation, {}x32",
        if em { "a: SSD" } else { "b: in-mem" },
        s.n
    ));
    // (label, recycle, fuse_mem, fuse_cache, strip_fusion, simd, cross_pass)
    let configs = [
        ("base", false, false, false, false, false, false),
        ("+mem-alloc", true, false, false, false, false, false),
        ("+mem-fuse", true, true, false, false, false, false),
        ("+cache-fuse", true, true, true, false, false, false),
        ("+strip-fusion", true, true, true, true, false, false),
        ("+simd", true, true, true, true, true, false),
        ("+cross-pass", true, true, true, true, true, true),
    ];
    for alg in ALL_ALGS {
        let mut base_secs = None;
        for (label, recycle, fm, fc, sf, simd, xp) in configs {
            let mut cfg = config_for(s, mode, s.threads);
            cfg.recycle_chunks = recycle;
            cfg.fuse_mem = fm;
            cfg.fuse_cache = fc;
            cfg.inplace_ops = sf;
            cfg.peephole_fuse = sf;
            cfg.simd_kernels = simd;
            cfg.cross_pass_opt = xp;
            cfg.xla_dispatch = false; // isolate the engine
            let eng = Engine::new(cfg)?;
            let x = dataset(&eng, s.n, 32)?;
            eng.ssd.drain_bursts();
            eng.metrics.reset();
            let secs = run_alg(&x, alg, 10, s.iters)?;
            let m = eng.metrics.snapshot();
            let speedup = base_secs.map(|b: f64| b / secs).unwrap_or(1.0);
            if base_secs.is_none() {
                base_secs = Some(secs);
            }
            t.add_with(
                format!("{} {}", alg.label(), label),
                speedup,
                "x vs base",
                vec![
                    ("secs".into(), secs),
                    ("buf_allocs".into(), m.buf_allocs as f64),
                    ("buf_reuses".into(), m.buf_reuses as f64),
                    ("inplace_ops".into(), m.inplace_ops as f64),
                    ("fused_len".into(), m.fused_chain_len as f64),
                    ("simd_strips".into(), m.simd_strips as f64),
                    ("simd_lanes".into(), m.simd_lanes_f64 as f64),
                    ("gemm_panels".into(), m.gemm_panels as f64),
                    ("passes".into(), m.passes_run as f64),
                    ("cse_hits".into(), m.opt_cse_hits as f64),
                    ("sinks_pruned".into(), m.opt_sinks_pruned as f64),
                    ("mat_decisions".into(), m.opt_mat_decisions as f64),
                ],
            );
        }
    }
    Ok(t)
}

/// Fig 12: VUDF vs per-element function calls (in memory, all memory
/// optimizations on — the paper's setup).
pub fn fig12(s: &Scale) -> Result<Table> {
    let mut t = Table::new(format!("Fig 12 VUDF effectiveness, {}x32 in-mem", s.n));
    for alg in ALL_ALGS {
        let mut base = None;
        for (label, vudf) in [("element-call", false), ("VUDF", true)] {
            let mut cfg = EngineConfig::fm_im();
            cfg.threads = s.threads;
            cfg.vectorized_udf = vudf;
            cfg.xla_dispatch = false;
            cfg.artifacts_dir = s.artifacts_dir.clone().into();
            let eng = Engine::new(cfg)?;
            let x = dataset(&eng, s.n, 32)?;
            let secs = run_alg(&x, alg, 10, s.iters)?;
            let speedup = base.map(|b: f64| b / secs).unwrap_or(1.0);
            if base.is_none() {
                base = Some(secs);
            }
            t.add_with(
                format!("{} {}", alg.label(), label),
                speedup,
                "x",
                vec![("secs".into(), secs)],
            );
        }
    }
    Ok(t)
}

/// Sparse-workload rows: PageRank over a synthetic edge matrix and
/// logistic regression (IRLS), each FM-IM vs FM-EM. The EM PageRank run
/// deliberately caps `em_cache_bytes` *below* the edge-matrix footprint,
/// so every power iteration re-streams edges through cache replacement —
/// the out-of-core scenario the SpMM GenOp exists for
/// (`benches/spmm_pagerank.rs` is the full ablation). Rank sums and
/// fitted coefficients are printed as sub-values so the rows double as a
/// correctness smoke.
pub fn sparse_workloads(s: &Scale) -> Result<Table> {
    let n = s.n.max(4096);
    let max_deg = 16u64;
    let mut t = Table::new(format!(
        "Sparse workloads: PageRank ({n} nodes, max_deg {max_deg}) + logistic ({}x8), {} threads",
        s.n, s.threads
    ));
    for mode in [Mode::FmIm, Mode::FmEm] {
        let mut cfg = config_for(s, mode, s.threads);
        if mode == Mode::FmEm {
            // cache smaller than the edge matrix: ~12 B/entry, halved
            cfg.em_cache_bytes = ((n * max_deg / 2) * 12 / 2) as usize;
            cfg.prefetch_depth = 2;
        }
        let eng = Engine::new(cfg)?;
        let (g, dangling) = crate::datasets::pagerank_graph(&eng, n, max_deg, 42, None)?;
        eng.metrics.reset();
        let t0 = Instant::now();
        let pr = algs::pagerank(&g, &dangling, 0.85, s.iters.max(5), 1e-10)?;
        let secs = t0.elapsed().as_secs_f64();
        let m = eng.metrics.snapshot();
        t.add_with(
            format!("pagerank {}", mode.label()),
            secs,
            "s",
            vec![
                ("iters".into(), pr.iterations as f64),
                ("rank_sum".into(), pr.ranks.iter().sum()),
                ("spmm_nnz".into(), m.spmm_nnz as f64),
                ("read_gb".into(), m.io_read_bytes as f64 / 1e9),
                ("cache_evictions".into(), m.cache_evictions as f64),
            ],
        );
    }
    for mode in [Mode::FmIm, Mode::FmEm] {
        let eng = engine_for(s, mode, s.threads)?;
        let x = crate::datasets::uniform(&eng, s.n, 8, -1.0, 1.0, 7, None)?;
        let beta_true = [1.0, -0.5, 0.25, -2.0, 0.0, 1.5, -1.0, 0.5];
        let y = crate::datasets::logistic_labels(&x, &beta_true, 9)?;
        eng.metrics.reset();
        let t0 = Instant::now();
        let fit = algs::logistic(&x, &y, s.iters.max(4), 1e-8)?;
        let secs = t0.elapsed().as_secs_f64();
        let m = eng.metrics.snapshot();
        t.add_with(
            format!("logistic {}", mode.label()),
            secs,
            "s",
            vec![
                ("beta0".into(), fit.beta[0]),
                ("deviance".into(), *fit.deviances.last().unwrap()),
                ("read_gb".into(), m.io_read_bytes as f64 / 1e9),
            ],
        );
    }
    Ok(t)
}

/// Write-back ablation rows (§III-B3, the write half of the I/O/compute
/// overlap): the same EM map-materialize workload under synchronous
/// write-through vs the asynchronous write-back pipeline, with a
/// partition cache far smaller than the matrix (cold reads) and the
/// deterministic SSD throttle. With write-back on, the pass worker's
/// throttled reads overlap the background writer's throttled writes, so
/// the pass approaches `max(read, write)` instead of `read + write`.
/// Rows carry the `wb_*` counters; `benches/writeback.rs` is the full
/// ablation with the strict wall-time and bit-exactness checks.
pub fn writeback_overlap(s: &Scale) -> Result<Table> {
    let n = s.n.max(1 << 18);
    let mut t = Table::new(format!(
        "Write-back overlap: EM sq() materialize, {n}x8, SSD {} MiB/s",
        s.ssd_bps >> 20
    ));
    for (label, writeback) in [("write-through", false), ("write-back", true)] {
        let mut cfg = config_for(s, Mode::FmEm, s.threads);
        // the cache must exist to host the writer thread, but stay far
        // smaller than the matrix so every pass re-streams cold;
        // read-ahead off to isolate the write lever (with it on, the
        // prefetch thread already hides reads behind synchronous writes)
        cfg.em_cache_bytes = 8 << 20;
        cfg.prefetch_depth = 0;
        cfg.writeback = writeback;
        let eng = Engine::new(cfg)?;
        let x = crate::datasets::uniform(&eng, n, 8, -1.0, 1.0, 7, None)?;
        if let Some(c) = &eng.cache {
            c.clear(); // generation's write-through copies: start cold
        }
        eng.ssd.drain_bursts(); // timed bytes pay the full rate
        eng.metrics.reset();
        let t0 = Instant::now();
        let y = x.sq()?.materialize()?;
        let secs = t0.elapsed().as_secs_f64();
        std::hint::black_box(y.nrow());
        let m = eng.metrics.snapshot();
        t.add_with(
            label,
            secs,
            "s",
            vec![
                ("wb_enqueued".into(), m.wb_enqueued as f64),
                ("wb_coalesced".into(), m.wb_coalesced as f64),
                ("wb_flush_waits".into(), m.wb_flush_waits as f64),
                ("wb_discarded".into(), m.wb_discarded as f64),
                ("read_gb".into(), m.io_read_bytes as f64 / 1e9),
                ("write_gb".into(), m.io_write_bytes as f64 / 1e9),
            ],
        );
    }
    Ok(t)
}

/// Table IV cross-check: measured I/O bytes per algorithm vs the paper's
/// I/O complexity (O(np) per pass), on the EM engine.
pub fn table4(s: &Scale) -> Result<Table> {
    let mut t = Table::new(format!("Table IV I/O cross-check, {}x32 EM", s.n));
    let np_bytes = (s.n * 32 * 8) as f64;
    for alg in ALL_ALGS {
        let eng = engine_for(s, Mode::FmEm, s.threads)?;
        let x = dataset(&eng, s.n, 32)?;
        eng.metrics.reset();
        run_alg(&x, alg, 10, s.iters)?;
        let read = eng.metrics.snapshot().io_read_bytes as f64;
        // passes over the data = read / (n*p*8); iterative algs divide by
        // iteration count for the per-iteration figure the table gives
        let passes = read / np_bytes;
        let per_iter = match alg {
            Alg::Kmeans | Alg::Gmm => passes / s.iters as f64,
            _ => passes,
        };
        t.add_with(
            alg.label().to_string(),
            per_iter,
            "data passes (per iter)",
            vec![("total_read_gb".into(), read / 1e9)],
        );
    }
    Ok(t)
}
