//! Multivariate statistical summary (paper §IV-A): column-wise min, max,
//! mean, L1 norm, L2 norm, non-zero count and variance — all in ONE
//! streaming pass (the six fused `fm.agg.col` sinks of the GenOp path, or
//! the Pallas colstats kernel on the XLA path).

use crate::dag::SinkResult;
use crate::error::Result;
use crate::fmr::FmMatrix;
use crate::runtime::HostTensor;
use crate::vudf::{AggOp, UnOp};

/// Column-wise summary statistics.
#[derive(Clone, Debug)]
pub struct SummaryResult {
    pub n: u64,
    pub min: Vec<f64>,
    pub max: Vec<f64>,
    pub mean: Vec<f64>,
    pub l1: Vec<f64>,
    pub l2: Vec<f64>,
    pub nnz: Vec<f64>,
    pub var: Vec<f64>,
}

impl SummaryResult {
    fn from_accumulators(
        n: u64,
        min: Vec<f64>,
        max: Vec<f64>,
        sum: Vec<f64>,
        sumsq: Vec<f64>,
        sumabs: Vec<f64>,
        nnz: Vec<f64>,
    ) -> SummaryResult {
        let nf = n as f64;
        let mean: Vec<f64> = sum.iter().map(|s| s / nf).collect();
        let var = sumsq
            .iter()
            .zip(&mean)
            .map(|(ss, m)| (ss - nf * m * m) / (nf - 1.0).max(1.0))
            .collect();
        let l2 = sumsq.iter().map(|s| s.sqrt()).collect();
        SummaryResult {
            n,
            min,
            max,
            mean,
            l1: sumabs,
            l2,
            nnz,
            var,
        }
    }
}

/// Compute the summary of a tall matrix.
pub fn summary(x: &FmMatrix) -> Result<SummaryResult> {
    if let Some((svc, name)) = super::xla_candidate(x, "summary", 0) {
        return summary_xla(x, &svc, &name);
    }
    summary_genop(x)
}

/// GenOp path: six `fm.agg.col` sinks over a shared scan (the paper's
/// fused R implementation — Fig 5's pattern without the NA handling).
pub fn summary_genop(x: &FmMatrix) -> Result<SummaryResult> {
    let n = x.nrow();
    let sq = x.sapply(UnOp::Sq)?;
    let ab = x.sapply(UnOp::Abs)?;
    let nz = x.sapply(UnOp::NotZero)?;
    let sinks = vec![
        x.agg_col_sink(AggOp::Min)?,
        x.agg_col_sink(AggOp::Max)?,
        x.agg_col_sink(AggOp::Sum)?,
        sq.agg_col_sink(AggOp::Sum)?,
        ab.agg_col_sink(AggOp::Sum)?,
        nz.agg_col_sink(AggOp::Sum)?,
    ];
    let rs = x.eng.materialize_sinks(&sinks)?;
    let take = |r: &SinkResult| -> Vec<f64> { r.mat().buf.to_f64_vec() };
    Ok(SummaryResult::from_accumulators(
        n,
        take(&rs[0]),
        take(&rs[1]),
        take(&rs[2]),
        take(&rs[3]),
        take(&rs[4]),
        take(&rs[5]),
    ))
}

/// XLA path: the Pallas colstats kernel per full partition, native step for
/// the tail, merged like any aVUDF combine.
fn summary_xla(
    x: &FmMatrix,
    svc: &crate::runtime::XlaService,
    name: &str,
) -> Result<SummaryResult> {
    let d = super::dense_of(x)?;
    let p = d.ncol() as usize;
    let mut min = vec![f64::INFINITY; p];
    let mut max = vec![f64::NEG_INFINITY; p];
    let mut sum = vec![0.0; p];
    let mut sumsq = vec![0.0; p];
    let mut sumabs = vec![0.0; p];
    let mut nnz = vec![0.0; p];
    for i in 0..d.parts.n_parts() {
        let stats: Vec<f64> = if d.parts.is_full(i) {
            let (rows, rm) = super::partition_row_major(d, i)?;
            x.eng
                .metrics
                .xla_dispatches
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let out = svc.run(name, vec![HostTensor::f64(vec![rows, p], rm)])?;
            out[0].as_f64()?.to_vec()
        } else {
            let buf = d.partition_buf(i)?;
            super::steps::colstats_native(&buf, d.parts.rows_in(i) as usize, p)?
        };
        for j in 0..p {
            min[j] = min[j].min(stats[j]);
            max[j] = max[j].max(stats[p + j]);
            sum[j] += stats[2 * p + j];
            sumsq[j] += stats[3 * p + j];
            sumabs[j] += stats[4 * p + j];
            nnz[j] += stats[5 * p + j];
        }
    }
    Ok(SummaryResult::from_accumulators(
        x.nrow(),
        min,
        max,
        sum,
        sumsq,
        sumabs,
        nnz,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::fmr::Engine;

    #[test]
    fn summary_matches_manual() {
        let e = Engine::new(EngineConfig {
            xla_dispatch: false,
            chunk_bytes: 1 << 20,
            target_part_bytes: 1 << 20,
            ..Default::default()
        })
        .unwrap();
        let x = crate::datasets::uniform(&e, 10_000, 3, -1.0, 3.0, 13, None).unwrap();
        let s = summary(&x).unwrap();
        assert_eq!(s.n, 10_000);
        for j in 0..3 {
            assert!(s.min[j] >= -1.0 && s.min[j] < -0.9);
            assert!(s.max[j] <= 3.0 && s.max[j] > 2.9);
            assert!((s.mean[j] - 1.0).abs() < 0.1);
            // var of U(-1,3) = 16/12 ≈ 1.333
            assert!((s.var[j] - 4.0 / 3.0).abs() < 0.1);
            assert_eq!(s.nnz[j], 10_000.0); // exact zeros have measure 0
            assert!(s.l1[j] > 0.0 && s.l2[j] > 0.0);
        }
    }
}
