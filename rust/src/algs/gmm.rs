//! Gaussian Mixture Model via EM (paper §IV-A), full covariance.
//!
//! The E-step is the paper's heaviest GenOp composition and fuses into ONE
//! streaming pass per iteration: for every component the Mahalanobis terms
//! are two `fm.inner.prod`s with small right operands plus element-wise
//! ops; the log-sum-exp, responsibilities, and ALL sufficient statistics
//! (`Nk`, `Sk`, per-component `SSk` second moments, log-likelihood) are
//! 2k + 3 sinks sharing the scan. The M-step is host-side (k p×p Cholesky
//! solves via [`super::linalg`]).
//!
//! The XLA path dispatches the entire E-step per partition to the gmm
//! artifact (lowered from `python/compile/model.py::gmm_estep`).

use crate::dtype::{DType, Scalar};
use crate::error::Result;
use crate::fmr::FmMatrix;
use crate::matrix::HostMat;
use crate::runtime::HostTensor;
use crate::vudf::{AggOp, BinOp};

/// GMM fit result.
#[derive(Clone, Debug)]
pub struct GmmResult {
    /// Component means, k×p.
    pub means: HostMat,
    /// Component covariances, row-major (k, p, p).
    pub covs: Vec<f64>,
    /// Mixing weights (length k).
    pub weights: Vec<f64>,
    /// Log-likelihood per iteration (monotone non-decreasing).
    pub loglik: Vec<f64>,
    pub iterations: usize,
}

/// Model parameters carried across iterations (host side).
struct Params {
    k: usize,
    p: usize,
    means_rm: Vec<f64>,   // (k,p)
    prec_rm: Vec<f64>,    // (k,p,p)
    logdet: Vec<f64>,     // of the precision
    logw: Vec<f64>,
}

/// Fit a k-component full-covariance GMM with `iters` EM iterations.
/// Initialization: k-means-style seeded means, identity covariance,
/// uniform weights.
pub fn gmm(x: &FmMatrix, k: usize, iters: usize, seed: u64) -> Result<GmmResult> {
    let p = x.ncol() as usize;
    let n = x.nrow() as f64;
    let means0 = super::kmeans::init_centroids(x, k, seed)?;
    let mut prm = Params {
        k,
        p,
        means_rm: means0.to_row_major_f64(),
        prec_rm: identity_stack(k, p),
        logdet: vec![0.0; k],
        logw: vec![(1.0 / k as f64).ln(); k],
    };

    let xla = super::xla_candidate(x, "gmm", k as u64);
    let mut ll_log = Vec::with_capacity(iters);
    for _it in 0..iters {
        let (nk, sk, ssk, ll) = match &xla {
            Some((svc, name)) => estep_xla(x, svc, name, &prm)?,
            None => estep_genop(x, &prm)?,
        };
        ll_log.push(ll);

        // ---- M-step (host): weights, means, covariances, precisions
        for c in 0..k {
            let nc = nk[c].max(1e-12);
            prm.logw[c] = (nc / n).ln();
            for j in 0..p {
                prm.means_rm[c * p + j] = sk[c * p + j] / nc;
            }
            // cov = SS/N - mu mu^T + eps I
            let mut cov = vec![0.0; p * p];
            for i in 0..p {
                for j in 0..p {
                    cov[i * p + j] = ssk[c * p * p + i * p + j] / nc
                        - prm.means_rm[c * p + i] * prm.means_rm[c * p + j];
                }
            }
            for i in 0..p {
                cov[i * p + i] += 1e-6; // regularization
            }
            let (inv, logdet_cov) = super::linalg::spd_inverse_logdet(&cov, p)?;
            prm.prec_rm[c * p * p..(c + 1) * p * p].copy_from_slice(&inv);
            prm.logdet[c] = -logdet_cov; // logdet of precision
        }
    }

    // reconstruct covariances for the result
    let mut covs = vec![0.0; k * p * p];
    for c in 0..k {
        let (inv, _ld) =
            super::linalg::spd_inverse_logdet(&prm.prec_rm[c * p * p..(c + 1) * p * p], p)?;
        covs[c * p * p..(c + 1) * p * p].copy_from_slice(&inv);
    }
    let means = HostMat::from_row_major_f64(k, p, &prm.means_rm);
    Ok(GmmResult {
        means,
        covs,
        weights: prm.logw.iter().map(|l| l.exp()).collect(),
        loglik: ll_log,
        iterations: iters,
    })
}

fn identity_stack(k: usize, p: usize) -> Vec<f64> {
    let mut v = vec![0.0; k * p * p];
    for c in 0..k {
        for i in 0..p {
            v[c * p * p + i * p + i] = 1.0;
        }
    }
    v
}

/// E-step through GenOps: one fused pass with 2k+3 sinks.
fn estep_genop(x: &FmMatrix, prm: &Params) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>, f64)> {
    let (k, p) = (prm.k, prm.p);
    let cst = -0.5 * p as f64 * (2.0 * std::f64::consts::PI).ln();

    // per-component log-density columns (all lazy)
    let mut logp_cols = Vec::with_capacity(k);
    for c in 0..k {
        // P_c as a p×p host operand (col-major HostMat from row-major slice)
        let pc = HostMat::from_row_major_f64(p, p, &prm.prec_rm[c * p * p..(c + 1) * p * p]);
        // pmu_c = P_c mu_c (p×1)
        let mut pmu = HostMat::zeros(p, 1, DType::F64);
        let mut mupmu = 0.0;
        for i in 0..p {
            let mut s = 0.0;
            for j in 0..p {
                s += prm.prec_rm[c * p * p + i * p + j] * prm.means_rm[c * p + j];
            }
            pmu.set(i, 0, Scalar::F64(s));
            mupmu += s * prm.means_rm[c * p + i];
        }
        let xp = x.inner_prod_small(&pc, BinOp::Mul, AggOp::Sum)?; // n×p
        let xpx = xp.mapply(x, BinOp::Mul)?.agg_row(AggOp::Sum)?; // n×1
        let xpm = x.inner_prod_small(&pmu, BinOp::Mul, AggOp::Sum)?; // n×1
        // logp_c = logw + 0.5 logdet - 0.5 (xpx - 2 xpm + mupmu) + cst
        let maha = xpx.mapply(&xpm.mul_scalar(-2.0)?, BinOp::Add)?.add_scalar(mupmu)?;
        let lp = maha
            .mul_scalar(-0.5)?
            .add_scalar(prm.logw[c] + 0.5 * prm.logdet[c] + cst)?;
        logp_cols.push(lp);
    }
    let refs: Vec<&FmMatrix> = logp_cols.iter().collect();
    let logp = FmMatrix::cbind(&x.eng, &refs)?; // n×k

    // log-sum-exp per row, responsibilities (all still lazy)
    let m = logp.agg_row(AggOp::Max)?;
    let sh = logp.mapply_col(&m, BinOp::Sub)?;
    let se = sh.exp()?.agg_row(AggOp::Sum)?;
    let lse = se.log()?.mapply(&m, BinOp::Add)?;
    let resp = logp.mapply_col(&lse, BinOp::Sub)?.exp()?; // n×k

    // sinks: Nk, Sk, loglik, and k second-moment Gramians
    let mut sinks = Vec::with_capacity(2 * k + 3);
    sinks.push(resp.agg_col_sink(AggOp::Sum)?); // 0: Nk (1×k)
    sinks.push(resp.t().inner_prod_wide_tall_sink(x, BinOp::Mul, AggOp::Sum)?); // 1: Sk (k×p)
    sinks.push(lse.agg_sink(AggOp::Sum)); // 2: loglik
    for c in 0..k {
        let rc = resp.col(c as u64)?;
        let xw = x.mapply_col(&rc, BinOp::Mul)?; // X scaled by resp[:,c]
        sinks.push(xw.t().inner_prod_wide_tall_sink(x, BinOp::Mul, AggOp::Sum)?);
    }
    let rs = x.eng.materialize_sinks(&sinks)?;

    let nk = rs[0].mat().buf.to_f64_vec();
    let sk = rs[1].mat().to_row_major_f64();
    let ll = rs[2].scalar().as_f64();
    let mut ssk = vec![0.0; k * p * p];
    for c in 0..k {
        let g = rs[3 + c].mat().to_row_major_f64();
        ssk[c * p * p..(c + 1) * p * p].copy_from_slice(&g);
    }
    Ok((nk, sk, ssk, ll))
}

/// E-step through the gmm artifact per full partition + native tail.
fn estep_xla(
    x: &FmMatrix,
    svc: &crate::runtime::XlaService,
    name: &str,
    prm: &Params,
) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>, f64)> {
    let d = super::dense_of(x)?;
    let (k, p) = (prm.k, prm.p);
    let mut nk = vec![0.0; k];
    let mut sk = vec![0.0; k * p];
    let mut ssk = vec![0.0; k * p * p];
    let mut ll = 0.0;
    for i in 0..d.parts.n_parts() {
        if d.parts.is_full(i) {
            let (rows, rm) = super::partition_row_major(d, i)?;
            x.eng
                .metrics
                .xla_dispatches
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let out = svc.run(
                name,
                vec![
                    HostTensor::f64(vec![rows, p], rm),
                    HostTensor::f64(vec![k, p], prm.means_rm.clone()),
                    HostTensor::f64(vec![k, p, p], prm.prec_rm.clone()),
                    HostTensor::f64(vec![k], prm.logdet.clone()),
                    HostTensor::f64(vec![k], prm.logw.clone()),
                ],
            )?;
            for (a, b) in nk.iter_mut().zip(out[0].as_f64()?) {
                *a += b;
            }
            for (a, b) in sk.iter_mut().zip(out[1].as_f64()?) {
                *a += b;
            }
            for (a, b) in ssk.iter_mut().zip(out[2].as_f64()?) {
                *a += b;
            }
            ll += out[3].as_f64()?[0];
        } else {
            let buf = d.partition_buf(i)?;
            let (n2, s2, ss2, l2) = super::steps::gmm_estep_native(
                &buf,
                d.parts.rows_in(i) as usize,
                p,
                &prm.means_rm,
                &prm.prec_rm,
                &prm.logdet,
                &prm.logw,
            )?;
            for (a, b) in nk.iter_mut().zip(n2) {
                *a += b;
            }
            for (a, b) in sk.iter_mut().zip(s2) {
                *a += b;
            }
            for (a, b) in ssk.iter_mut().zip(ss2) {
                *a += b;
            }
            ll += l2;
        }
    }
    Ok((nk, sk, ssk, ll))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::fmr::Engine;

    #[test]
    fn gmm_loglik_increases_and_recovers_means() {
        let e = Engine::new(EngineConfig {
            xla_dispatch: false,
            chunk_bytes: 1 << 20,
            target_part_bytes: 1 << 20,
            ..Default::default()
        })
        .unwrap();
        let (x, means) = crate::datasets::mix_gaussian(&e, 12_000, 3, 2, 10.0, 31, None).unwrap();
        let r = gmm(&x, 2, 6, 3).unwrap();
        // EM monotonicity
        for w in r.loglik.windows(2) {
            assert!(w[1] >= w[0] - 1e-6, "loglik decreased: {w:?}");
        }
        // weights sum to 1
        let ws: f64 = r.weights.iter().sum();
        assert!((ws - 1.0).abs() < 1e-9);
        // each fitted mean near a true mean
        for c in 0..2 {
            let best = (0..2)
                .map(|t| {
                    (0..3)
                        .map(|j| {
                            let d = r.means.get(c, j).as_f64() - means.get(t, j).as_f64();
                            d * d
                        })
                        .sum::<f64>()
                })
                .fold(f64::INFINITY, f64::min);
            assert!(best < 1.0, "mean {c} off by {best}");
        }
        // covariances near identity (the generative covariance)
        for c in 0..2 {
            for i in 0..3 {
                let v = r.covs[c * 9 + i * 3 + i];
                assert!((v - 1.0).abs() < 0.3, "cov[{c},{i},{i}] = {v}");
            }
        }
    }
}
