//! Small dense linear algebra on host matrices (p ≤ a few hundred).
//!
//! The paper computes SVD as "Gramian + eigendecomposition" via external
//! eigensolvers [35,36]; this substrate provides the eigensolver (cyclic
//! Jacobi — simple, robust for symmetric p×p) plus the Cholesky pieces GMM
//! needs (inverse + log-determinant of covariance matrices).

use crate::error::{FmError, Result};
use crate::matrix::HostMat;

/// Symmetric eigendecomposition by cyclic Jacobi rotations.
///
/// Input: symmetric `a` (p×p, row-major). Returns `(eigenvalues,
/// eigenvectors)` sorted by descending eigenvalue; eigenvector `i` is
/// column `i` of the returned p×p row-major matrix.
pub fn jacobi_eigen(a: &[f64], p: usize, max_sweeps: usize) -> Result<(Vec<f64>, Vec<f64>)> {
    if a.len() != p * p {
        return Err(FmError::Shape(format!(
            "jacobi: expected {}x{} matrix",
            p, p
        )));
    }
    let mut m = a.to_vec();
    // V = I
    let mut v = vec![0.0; p * p];
    for i in 0..p {
        v[i * p + i] = 1.0;
    }
    let idx = |r: usize, c: usize| r * p + c;

    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius norm
        let mut off = 0.0;
        for r in 0..p {
            for c in (r + 1)..p {
                off += m[idx(r, c)] * m[idx(r, c)];
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
        for r in 0..p {
            for c in (r + 1)..p {
                let apq = m[idx(r, c)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[idx(r, r)];
                let aqq = m[idx(c, c)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let cos = 1.0 / (t * t + 1.0).sqrt();
                let sin = t * cos;
                // rotate rows/cols r and c of m
                for k in 0..p {
                    let mrk = m[idx(r, k)];
                    let mck = m[idx(c, k)];
                    m[idx(r, k)] = cos * mrk - sin * mck;
                    m[idx(c, k)] = sin * mrk + cos * mck;
                }
                for k in 0..p {
                    let mkr = m[idx(k, r)];
                    let mkc = m[idx(k, c)];
                    m[idx(k, r)] = cos * mkr - sin * mkc;
                    m[idx(k, c)] = sin * mkr + cos * mkc;
                }
                // accumulate V
                for k in 0..p {
                    let vkr = v[idx(k, r)];
                    let vkc = v[idx(k, c)];
                    v[idx(k, r)] = cos * vkr - sin * vkc;
                    v[idx(k, c)] = sin * vkr + cos * vkc;
                }
            }
        }
    }

    let mut order: Vec<usize> = (0..p).collect();
    let evals: Vec<f64> = (0..p).map(|i| m[idx(i, i)]).collect();
    order.sort_by(|&i, &j| evals[j].partial_cmp(&evals[i]).unwrap());
    let sorted_vals: Vec<f64> = order.iter().map(|&i| evals[i]).collect();
    let mut sorted_vecs = vec![0.0; p * p];
    for (new_c, &old_c) in order.iter().enumerate() {
        for r in 0..p {
            sorted_vecs[idx(r, new_c)] = v[idx(r, old_c)];
        }
    }
    Ok((sorted_vals, sorted_vecs))
}

/// Cholesky factorization of a symmetric positive-definite matrix
/// (row-major). Returns the lower factor L with `a = L L^T`.
pub fn cholesky(a: &[f64], p: usize) -> Result<Vec<f64>> {
    let mut l = vec![0.0; p * p];
    for i in 0..p {
        for j in 0..=i {
            let mut s = a[i * p + j];
            for k in 0..j {
                s -= l[i * p + k] * l[j * p + k];
            }
            if i == j {
                if s <= 0.0 {
                    return Err(FmError::Shape(format!(
                        "cholesky: matrix not positive definite (pivot {i}: {s})"
                    )));
                }
                l[i * p + i] = s.sqrt();
            } else {
                l[i * p + j] = s / l[j * p + j];
            }
        }
    }
    Ok(l)
}

/// Inverse and log-determinant of an SPD matrix via Cholesky.
pub fn spd_inverse_logdet(a: &[f64], p: usize) -> Result<(Vec<f64>, f64)> {
    let l = cholesky(a, p)?;
    let logdet = 2.0 * (0..p).map(|i| l[i * p + i].ln()).sum::<f64>();
    // invert L (lower triangular)
    let mut linv = vec![0.0; p * p];
    for i in 0..p {
        linv[i * p + i] = 1.0 / l[i * p + i];
        for j in 0..i {
            let mut s = 0.0;
            for k in j..i {
                s -= l[i * p + k] * linv[k * p + j];
            }
            linv[i * p + j] = s / l[i * p + i];
        }
    }
    // a^-1 = L^-T L^-1
    let mut inv = vec![0.0; p * p];
    for i in 0..p {
        for j in 0..p {
            let mut s = 0.0;
            for k in i.max(j)..p {
                s += linv[k * p + i] * linv[k * p + j];
            }
            inv[i * p + j] = s;
        }
    }
    Ok((inv, logdet))
}

/// Row-major matmul of small host matrices: (m×k) @ (k×n).
pub fn matmul_rm(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut out = vec![0.0; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av != 0.0 {
                for j in 0..n {
                    out[i * n + j] += av * b[kk * n + j];
                }
            }
        }
    }
    out
}

/// Convenience: HostMat (col-major) -> row-major Vec.
pub fn host_to_rm(h: &HostMat) -> Vec<f64> {
    h.to_row_major_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jacobi_diagonalizes_known_matrix() {
        // [[2,1],[1,2]] -> eigenvalues 3, 1; eigvecs (1,1)/√2, (1,-1)/√2
        let (vals, vecs) = jacobi_eigen(&[2.0, 1.0, 1.0, 2.0], 2, 50).unwrap();
        assert!((vals[0] - 3.0).abs() < 1e-10);
        assert!((vals[1] - 1.0).abs() < 1e-10);
        let v0 = (vecs[0], vecs[2]); // column 0
        assert!((v0.0.abs() - (0.5f64).sqrt()).abs() < 1e-8);
        assert!((v0.0 - v0.1).abs() < 1e-8); // equal components
    }

    #[test]
    fn jacobi_reconstructs() {
        // A = V diag(w) V^T for a random symmetric 5x5
        let p = 5;
        let mut a = vec![0.0; p * p];
        for i in 0..p {
            for j in 0..p {
                let v = ((i * 31 + j * 17) % 13) as f64 / 13.0;
                a[i * p + j] += v;
                a[j * p + i] += v;
            }
        }
        let (w, v) = jacobi_eigen(&a, p, 100).unwrap();
        // rebuild
        let mut rec = vec![0.0; p * p];
        for i in 0..p {
            for j in 0..p {
                for k in 0..p {
                    rec[i * p + j] += v[i * p + k] * w[k] * v[j * p + k];
                }
            }
        }
        for (x, y) in rec.iter().zip(a.iter()) {
            assert!((x - y).abs() < 1e-8, "{x} vs {y}");
        }
        // eigenvalues descending
        for k in 1..p {
            assert!(w[k - 1] >= w[k] - 1e-12);
        }
    }

    #[test]
    fn spd_inverse_roundtrip() {
        let p = 3;
        // A = M M^T + I is SPD
        let m = [1.0, 2.0, 0.5, 0.0, 1.0, -1.0, 2.0, 0.3, 0.7];
        let mut a = vec![0.0; 9];
        for i in 0..p {
            for j in 0..p {
                for k in 0..p {
                    a[i * p + j] += m[i * p + k] * m[j * p + k];
                }
            }
            a[i * p + i] += 1.0;
        }
        let (inv, logdet) = spd_inverse_logdet(&a, p).unwrap();
        let prod = matmul_rm(&a, &inv, p, p, p);
        for i in 0..p {
            for j in 0..p {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod[i * p + j] - want).abs() < 1e-10);
            }
        }
        assert!(logdet.is_finite());
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        assert!(cholesky(&[1.0, 2.0, 2.0, 1.0], 2).is_err());
    }
}
