//! PageRank by power iteration over a sparse edge matrix — the
//! graph-style workload class of FlashR's evaluation, expressed entirely
//! in GenOps: each iteration is one *planned batch*
//! ([`crate::fmr::engine::Engine::plan_batch`]); under `cross_pass_opt`
//! a single streaming SpMM pass fuses the multiply, the damping
//! scale/shift and the L1 convergence sink.
//!
//! ```text
//! y      <- fm.multiply(G, r)                      # SpMM, G sparse n×n
//! r'     <- d * y + ((1-d) + d*dangling_mass)/n    # mapply.scalar ×2
//! delta  <- sum(abs(r' - r))                       # agg sink, same pass
//! ```
//!
//! `G` is the transposed, column-stochastic transition matrix (row `i` =
//! in-edges `j -> i` weighted `1/outdeg(j)`; see
//! [`crate::datasets::pagerank_graph`]); the rank vector stays a small
//! in-memory operand while the edge matrix streams from SSD — the paper's
//! out-of-core shape. Dangling mass is folded from the host-resident rank
//! vector in fixed index order, so ranks are bit-deterministic across
//! thread counts and storage modes (the EM/IM parity the golden test
//! pins).

use crate::dtype::{DType, Scalar};
use crate::error::{FmError, Result};
use crate::fmr::FmMatrix;
use crate::genops;
use crate::matrix::{DenseBuilder, HostMat, Matrix, MatrixData, Partitioning};
use crate::plan::PlanRequest;
use crate::vudf::{AggOp, Buf};

/// PageRank output.
#[derive(Clone, Debug)]
pub struct PagerankResult {
    /// Final ranks (length n, sums to 1 up to rounding).
    pub ranks: Vec<f64>,
    /// L1 change per iteration (monotone decreasing on a fixed graph).
    pub deltas: Vec<f64>,
    pub iterations: usize,
}

/// Run power iteration until `delta <= tol` or `max_iters`.
///
/// * `g` — sparse n×n transition matrix, transposed and column-stochastic.
/// * `dangling[j]` — whether node `j` has no out-edges (its rank mass is
///   redistributed uniformly, the standard correction).
pub fn pagerank(
    g: &FmMatrix,
    dangling: &[bool],
    damping: f64,
    max_iters: usize,
    tol: f64,
) -> Result<PagerankResult> {
    if !g.is_sparse() {
        return Err(FmError::Unsupported(
            "pagerank: edge matrix must be sparse".into(),
        ));
    }
    let n = g.nrow();
    if g.ncol() != n {
        return Err(FmError::Shape(format!(
            "pagerank: edge matrix must be square, got {}x{}",
            n,
            g.ncol()
        )));
    }
    if dangling.len() != n as usize {
        return Err(FmError::Shape(format!(
            "pagerank: dangling mask has {} entries for {n} nodes",
            dangling.len()
        )));
    }
    let io_rows = match &*g.m.data {
        MatrixData::Sparse(s) => s.parts.io_rows,
        _ => unreachable!("checked sparse above"),
    };

    let nf = n as f64;
    let mut r_host = vec![1.0 / nf; n as usize];
    // previous-iteration ranks as an engine matrix, partitioned on the
    // sparse io-row grid so every iteration's pass keeps one locality
    // unit per edge partition
    let mut r_prev = uniform_vector(g, 1.0 / nf, io_rows)?;

    let mut deltas = Vec::new();
    let mut iterations = 0;
    for _ in 0..max_iters {
        iterations += 1;
        // dangling mass folds from the host vector in fixed index order:
        // deterministic regardless of threads/storage
        let mut dmass = 0.0;
        for (d, r) in dangling.iter().zip(&r_host) {
            if *d {
                dmass += *r;
            }
        }
        let shift = ((1.0 - damping) + damping * dmass) / nf;

        let rh = HostMat::new(n as usize, 1, Buf::from_f64(&r_host))?;
        let r_new = g
            .spmm(rh)?
            .mul_scalar(damping)?
            .add_scalar(shift)?;
        let diff = r_new.sub(&r_prev)?.abs()?;
        // one planned batch per iteration: the new-rank target and the
        // L1-change sink share the SpMM chain, so under `cross_pass_opt`
        // both ride a single edge-matrix scan; eager mode streams the
        // edges once per statement
        let out = g.eng.plan_batch(&[
            PlanRequest::target(&r_new.m.canonical()),
            PlanRequest::sink(genops::agg_full(&diff.m, AggOp::Sum)),
        ])?;
        let r_mat = out[0].clone().target();
        let delta = out[1].clone().sink().scalar().as_f64();

        r_prev = FmMatrix {
            eng: std::sync::Arc::clone(&g.eng),
            m: r_mat,
        };
        r_host = r_prev.to_host()?.buf.to_f64_vec();
        deltas.push(delta);
        if delta <= tol {
            break;
        }
    }
    Ok(PagerankResult {
        ranks: r_host,
        deltas,
        iterations,
    })
}

/// Constant n×1 dense vector on the sparse matrix's io-row grid (the
/// initial uniform rank vector). Host-resident by construction — the rank
/// vector is the "small dense" side of the SpMM even in EM mode.
fn uniform_vector(g: &FmMatrix, value: f64, io_rows: u64) -> Result<FmMatrix> {
    let n = g.nrow();
    let parts = Partitioning::with_io_rows(n, 1, io_rows);
    let b = DenseBuilder::new_mem(DType::F64, parts.clone(), &g.eng.pool)?;
    for i in 0..parts.n_parts() {
        let prows = parts.rows_in(i) as usize;
        let mut buf = Buf::alloc(DType::F64, prows);
        buf.fill_scalar(Scalar::F64(value));
        b.write_partition_buf(i, &buf)?;
    }
    Ok(FmMatrix {
        eng: std::sync::Arc::clone(&g.eng),
        m: Matrix::from_dense(b.finish()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::datasets;
    use crate::fmr::Engine;

    fn eng() -> std::sync::Arc<Engine> {
        Engine::new(EngineConfig {
            xla_dispatch: false,
            chunk_bytes: 4 << 20,
            target_part_bytes: 1 << 20,
            ..Default::default()
        })
        .unwrap()
    }

    /// Dense host-side PageRank oracle over the same generator.
    fn host_pagerank(
        n: usize,
        max_deg: u64,
        seed: u64,
        damping: f64,
        iters: usize,
    ) -> Vec<f64> {
        let mut a = vec![0.0f64; n * n]; // row-major transposed transition
        let mut dangling = vec![false; n];
        for v in 0..n as u64 {
            let deg = crate::exec::splitmix64_at(seed ^ 0xDE66, v) % (max_deg + 1);
            if deg == 0 {
                dangling[v as usize] = true;
                continue;
            }
            for t in 0..deg {
                let u = crate::exec::splitmix64_at(seed, v * max_deg + t) % n as u64;
                a[u as usize * n + v as usize] += 1.0 / deg as f64;
            }
        }
        let mut r = vec![1.0 / n as f64; n];
        for _ in 0..iters {
            let dmass: f64 = (0..n).filter(|i| dangling[*i]).map(|i| r[i]).sum();
            let shift = ((1.0 - damping) + damping * dmass) / n as f64;
            let mut rn = vec![0.0; n];
            for (i, out) in rn.iter_mut().enumerate() {
                let mut acc = 0.0;
                for (aij, rj) in a[i * n..(i + 1) * n].iter().zip(&r) {
                    acc += aij * rj;
                }
                *out = damping * acc + shift;
            }
            r = rn;
        }
        r
    }

    #[test]
    fn matches_dense_oracle_and_conserves_mass() {
        let e = eng();
        let (g, dangling) = datasets::pagerank_graph(&e, 300, 6, 17, None).unwrap();
        assert!(g.is_sparse());
        let pr = pagerank(&g, &dangling, 0.85, 15, 0.0).unwrap();
        let want = host_pagerank(300, 6, 17, 0.85, 15);
        for (i, (a, b)) in pr.ranks.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-12, "rank[{i}]: {a} vs {b}");
        }
        let total: f64 = pr.ranks.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "rank mass {total}");
        // deltas shrink monotonically on a fixed graph
        for w in pr.deltas.windows(2) {
            assert!(w[1] <= w[0] * 1.0001, "delta not contracting: {w:?}");
        }
    }

    #[test]
    fn tolerance_stops_early() {
        let e = eng();
        let (g, dangling) = datasets::pagerank_graph(&e, 200, 5, 3, None).unwrap();
        // contraction factor ~0.85 per iteration: 1e-6 is reachable well
        // inside 200 iterations (~80), so the tolerance must cut the loop
        let pr = pagerank(&g, &dangling, 0.85, 200, 1e-6).unwrap();
        assert!(pr.iterations < 200, "tolerance must stop early");
        assert!(*pr.deltas.last().unwrap() <= 1e-6);
    }

    #[test]
    fn rejects_dense_input() {
        let e = eng();
        let x = datasets::uniform(&e, 100, 4, 0.0, 1.0, 1, None).unwrap();
        assert!(pagerank(&x, &[false; 100], 0.85, 3, 0.0).is_err());
    }
}
