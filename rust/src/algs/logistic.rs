//! Logistic regression by IRLS (Fisher scoring) — the GLM workload class
//! of FlashR's evaluation, expressed through the existing Gramian path:
//! each iteration submits its three sinks as one *planned batch*
//! ([`crate::fmr::engine::Engine::plan_batch`]) — a single streaming pass
//! over X under `cross_pass_opt`, three eager passes without — then a
//! tiny host-side solve.
//!
//! ```text
//! eta  <- X %*% beta                         # inner.prod (in-DAG)
//! mu   <- 1 / (1 + exp(-eta))                # sapply chain
//! w    <- mu * (1 - mu)                      # mapply
//! XtWX <- fm.inner.prod(t(X*w), X, *, +)     # sink 1 (crossprod shape)
//! grad <- fm.inner.prod(t(X), y - mu, *, +)  # sink 2
//! ll   <- sum(y*eta - softplus(eta))         # sink 3 (deviance)
//! beta <- beta + solve(XtWX + ridge I, grad) # host: spd_inverse_logdet
//! ```
//!
//! The Newton step solves through the same Cholesky substrate GMM uses
//! ([`super::linalg::spd_inverse_logdet`]); `softplus` is built from
//! GenOp primitives in the overflow-safe `max(x,0) + log(1+exp(-|x|))`
//! form.

use crate::dtype::Scalar;
use crate::error::{FmError, Result};
use crate::fmr::FmMatrix;
use crate::matrix::HostMat;
use crate::plan::PlanRequest;
use crate::vudf::{AggOp, BinOp};

use super::linalg::{matmul_rm, spd_inverse_logdet};

/// Logistic-regression output.
#[derive(Clone, Debug)]
pub struct LogisticResult {
    /// Fitted coefficients (length p).
    pub beta: Vec<f64>,
    /// Deviance (-2 log-likelihood) per iteration (monotone decreasing).
    pub deviances: Vec<f64>,
    pub iterations: usize,
}

/// Fit `P(y=1|x) = sigmoid(x beta)` with `iters` IRLS steps from beta=0.
/// `ridge` (e.g. 1e-8) keeps the information matrix SPD under perfect
/// separation.
pub fn logistic(x: &FmMatrix, y: &FmMatrix, iters: usize, ridge: f64) -> Result<LogisticResult> {
    let n = x.nrow();
    let p = x.ncol() as usize;
    if y.nrow() != n || y.ncol() != 1 {
        return Err(FmError::Shape(format!(
            "logistic: labels must be {n}x1, got {}x{}",
            y.nrow(),
            y.ncol()
        )));
    }
    let y64 = y.cast(crate::dtype::DType::F64)?;
    let mut beta = vec![0.0f64; p];
    let mut deviances = Vec::with_capacity(iters);

    for _ in 0..iters {
        let mut bh = HostMat::zeros(p, 1, crate::dtype::DType::F64);
        for (j, b) in beta.iter().enumerate() {
            bh.set(j, 0, Scalar::F64(*b));
        }
        let eta = x.matmul_small(&bh)?;
        let mu = eta.sigmoid()?;
        // IRLS weights w = mu (1 - mu)
        let one_minus_mu = mu.mapply_scalar(Scalar::F64(1.0), BinOp::Sub, false)?;
        let w = mu.mapply(&one_minus_mu, BinOp::Mul)?;

        // three sinks share one scan of X (fm.materialize on a batch)
        let xw = x.mapply_col(&w, BinOp::Mul)?;
        let s_xtwx = xw.t().inner_prod_wide_tall_sink(x, BinOp::Mul, AggOp::Sum)?;
        let resid = y64.sub(&mu)?;
        let s_grad = x.t().inner_prod_wide_tall_sink(&resid, BinOp::Mul, AggOp::Sum)?;
        // log-likelihood: sum(y*eta - softplus(eta)), softplus in the
        // overflow-safe form max(eta, 0) + log(1 + exp(-|eta|))
        let softplus = eta
            .mapply_scalar(Scalar::F64(0.0), BinOp::Max, true)?
            .add(&eta.abs()?.neg()?.exp()?.add_scalar(1.0)?.log()?)?;
        let s_ll = y64.mul(&eta)?.sub(&softplus)?.agg_sink(AggOp::Sum);
        // one planned batch per IRLS step: the optimizer shares the eta/mu
        // chain across the sinks and fuses them onto one scan of X
        let res = x.eng.plan_batch(&[
            PlanRequest::sink(s_xtwx),
            PlanRequest::sink(s_grad),
            PlanRequest::sink(s_ll),
        ])?;

        // host-side Newton step through the Cholesky substrate
        let mut xtwx = res[0].clone().sink().mat().to_row_major_f64();
        for j in 0..p {
            xtwx[j * p + j] += ridge;
        }
        let (inv, _logdet) = spd_inverse_logdet(&xtwx, p)?;
        let grad = res[1].clone().sink().mat().to_row_major_f64();
        let step = matmul_rm(&inv, &grad, p, p, 1);
        for (b, s) in beta.iter_mut().zip(&step) {
            *b += s;
        }
        deviances.push(-2.0 * res[2].clone().sink().scalar().as_f64());
    }
    Ok(LogisticResult {
        beta,
        deviances,
        iterations: iters,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::datasets;
    use crate::fmr::Engine;

    fn eng() -> std::sync::Arc<Engine> {
        Engine::new(EngineConfig {
            xla_dispatch: false,
            chunk_bytes: 4 << 20,
            target_part_bytes: 1 << 20,
            ..Default::default()
        })
        .unwrap()
    }

    /// Host-side IRLS oracle over explicit row-major data.
    fn host_irls(xs: &[Vec<f64>], ys: &[f64], iters: usize, ridge: f64) -> Vec<f64> {
        let n = xs.len();
        let p = xs[0].len();
        let mut beta = vec![0.0; p];
        for _ in 0..iters {
            let mut xtwx = vec![0.0; p * p];
            let mut grad = vec![0.0; p];
            for r in 0..n {
                let eta: f64 = (0..p).map(|j| xs[r][j] * beta[j]).sum();
                let mu = 1.0 / (1.0 + (-eta).exp());
                let w = mu * (1.0 - mu);
                for i in 0..p {
                    grad[i] += xs[r][i] * (ys[r] - mu);
                    for j in 0..p {
                        xtwx[i * p + j] += w * xs[r][i] * xs[r][j];
                    }
                }
            }
            for j in 0..p {
                xtwx[j * p + j] += ridge;
            }
            let (inv, _) = spd_inverse_logdet(&xtwx, p).unwrap();
            let step = matmul_rm(&inv, &grad, p, p, 1);
            for (b, s) in beta.iter_mut().zip(&step) {
                *b += s;
            }
        }
        beta
    }

    #[test]
    fn recovers_planted_coefficients() {
        let e = eng();
        let n = 20_000;
        let beta_true = [1.5, -2.0, 0.75];
        let x = datasets::uniform(&e, n, 3, -1.0, 1.0, 11, None).unwrap();
        let y = datasets::logistic_labels(&x, &beta_true, 13).unwrap();
        let fit = logistic(&x, &y, 8, 1e-10).unwrap();
        for (j, (b, t)) in fit.beta.iter().zip(&beta_true).enumerate() {
            assert!(
                (b - t).abs() < 0.15,
                "beta[{j}] = {b}, planted {t} (n = {n})"
            );
        }
        // deviance decreases monotonically under IRLS
        for w in fit.deviances.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "deviance increased: {w:?}");
        }
    }

    #[test]
    fn matches_host_irls_oracle() {
        let e = eng();
        let n = 4000usize;
        let x = datasets::uniform(&e, n as u64, 2, -2.0, 2.0, 5, None).unwrap();
        let y = datasets::logistic_labels(&x, &[0.5, -1.0], 6).unwrap();
        let fit = logistic(&x, &y, 6, 1e-8).unwrap();

        let xh = x.to_host().unwrap();
        let yh = y.to_host().unwrap().buf.to_f64_vec();
        let xs: Vec<Vec<f64>> = (0..n)
            .map(|r| (0..2).map(|c| xh.get(r, c).as_f64()).collect())
            .collect();
        let want = host_irls(&xs, &yh, 6, 1e-8);
        for (j, (b, w)) in fit.beta.iter().zip(&want).enumerate() {
            assert!(
                (b - w).abs() < 1e-9 * w.abs().max(1.0),
                "beta[{j}]: engine {b} vs oracle {w}"
            );
        }
    }

    #[test]
    fn shape_validation() {
        let e = eng();
        let x = datasets::uniform(&e, 100, 2, 0.0, 1.0, 1, None).unwrap();
        let bad = datasets::uniform(&e, 50, 1, 0.0, 1.0, 2, None).unwrap();
        assert!(logistic(&x, &bad, 2, 0.0).is_err());
    }
}
