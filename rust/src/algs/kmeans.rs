//! K-means clustering (Lloyd's algorithm, paper §IV-A).
//!
//! The GenOp path expresses one iteration exactly as the paper's R code
//! would — and the whole iteration fuses into ONE streaming pass:
//!
//! ```text
//! D      <- fm.mapply.col(fm.mapply.row(X %*% (-2 t(C)), colSums(C^2), +),
//!                         rowSums(X^2), +)          # squared distances
//! labels <- fm.agg.row(D, which.min) - 1
//! sums   <- fm.groupby.row(X, labels, +)            # sink 1
//! counts <- fm.groupby.row(1, labels, +)            # sink 2
//! wcss   <- sum(fm.agg.row(D, min))                 # sink 3
//! ```
//!
//! The iteration is submitted as one *planned batch*
//! ([`crate::fmr::engine::Engine::plan_batch`]): with `cross_pass_opt` on,
//! the cross-pass optimizer CSEs the shared distance DAG and fuses all
//! three sinks back into ONE scan of X (the paper's `fm.materialize` on
//! several sinks); with it off, each statement runs as its own eager pass
//! — the ablation `benches/cross_pass.rs` measures. The M-step is a
//! trivial host-side division. The XLA path dispatches the fused
//! per-partition step to the kmeans artifact (Pallas distance kernel +
//! one-hot matmul accumulation).

use crate::dtype::Scalar;
use crate::error::Result;
use crate::fmr::{EngineExt, FmMatrix};
use crate::matrix::HostMat;
use crate::plan::PlanRequest;
use crate::runtime::HostTensor;
use crate::vudf::{AggOp, BinOp};

/// K-means output.
#[derive(Clone, Debug)]
pub struct KmeansResult {
    /// Final centroids, k×p.
    pub centroids: HostMat,
    /// Within-cluster sum of squares per iteration (monotone decreasing).
    pub wcss: Vec<f64>,
    /// Points per cluster at the last iteration.
    pub sizes: Vec<f64>,
    pub iterations: usize,
}

/// Run `iters` Lloyd iterations from deterministic seeding (k rows of X
/// sampled by hash of the seed).
pub fn kmeans(x: &FmMatrix, k: usize, iters: usize, seed: u64) -> Result<KmeansResult> {
    let p = x.ncol() as usize;
    let mut c = init_centroids(x, k, seed)?;
    let mut wcss_log = Vec::with_capacity(iters);
    let mut sizes = vec![0.0; k];

    let xla = super::xla_candidate(x, "kmeans", k as u64);
    for _it in 0..iters {
        let (sums, counts, wcss) = match &xla {
            Some((svc, name)) => step_xla(x, svc, name, &c, k)?,
            None => step_genop(x, &c, k)?,
        };
        // M-step (host): mean of assigned points; empty clusters keep
        // their previous centroid (the standard Lloyd fallback).
        for ci in 0..k {
            if counts[ci] > 0.0 {
                for j in 0..p {
                    c.set(ci, j, Scalar::F64(sums[ci * p + j] / counts[ci]));
                }
            }
        }
        wcss_log.push(wcss);
        sizes = counts;
    }
    Ok(KmeansResult {
        centroids: c,
        wcss: wcss_log,
        sizes,
        iterations: iters,
    })
}

/// Deterministic greedy farthest-point initialization (k-means++-style):
/// a hash-seeded first centroid, then k-1 rounds picking the sample row
/// farthest from the chosen set. The candidate pool is the first I/O
/// partition (one read), which is a uniform sample for our generators.
pub fn init_centroids(x: &FmMatrix, k: usize, seed: u64) -> Result<HostMat> {
    let p = x.ncol() as usize;
    let d = super::dense_of(x)?;
    let buf = d.partition_buf(0)?;
    let prows = d.parts.rows_in(0) as usize;
    // subsample candidates for O(cand * k) work
    let cand_n = prows.min(4096);
    let stride = (prows / cand_n).max(1);
    let row_of = |ci: usize| ci * stride % prows;
    let get = |r: usize, j: usize| buf.get(j * prows + r).as_f64();

    let mut chosen: Vec<usize> = vec![(crate::exec::splitmix64_at(seed, 0) as usize) % prows];
    let mut mind = vec![f64::INFINITY; cand_n];
    while chosen.len() < k {
        let last = *chosen.last().unwrap();
        let mut best = (0usize, f64::NEG_INFINITY);
        for ci in 0..cand_n {
            let r = row_of(ci);
            let mut dd = 0.0;
            for j in 0..p {
                let diff = get(r, j) - get(last, j);
                dd += diff * diff;
            }
            if dd < mind[ci] {
                mind[ci] = dd;
            }
            if mind[ci] > best.1 {
                best = (r, mind[ci]);
            }
        }
        chosen.push(best.0);
    }
    let mut c = HostMat::zeros(k, p, crate::dtype::DType::F64);
    for (ci, &r) in chosen.iter().enumerate() {
        for j in 0..p {
            c.set(ci, j, buf.get(j * prows + r));
        }
    }
    Ok(c)
}

/// One Lloyd iteration through GenOps: a planned batch of 3 sinks (one
/// fused pass under `cross_pass_opt`, three eager passes without).
fn step_genop(x: &FmMatrix, c: &HostMat, k: usize) -> Result<(Vec<f64>, Vec<f64>, f64)> {
    let p = x.ncol() as usize;
    // -2 * t(C): p×k host operand of the inner product
    let mut ct2 = HostMat::zeros(p, k, crate::dtype::DType::F64);
    let mut c2 = HostMat::zeros(1, k, crate::dtype::DType::F64);
    for ci in 0..k {
        let mut s = 0.0;
        for j in 0..p {
            let v = c.get(ci, j).as_f64();
            ct2.set(j, ci, Scalar::F64(-2.0 * v));
            s += v * v;
        }
        c2.set(0, ci, Scalar::F64(s));
    }
    let x2 = x.sq()?.row_sums()?; // n×1, stays lazy
    let d = x
        .inner_prod_small(&ct2, BinOp::Mul, AggOp::Sum)? // X @ -2C^T
        .mapply_row(&c2, BinOp::Add)? // + ||c||²
        .mapply_col(&x2, BinOp::Add)?; // + ||x||²
    let labels = d
        .which_min_row()?
        .mapply_scalar(Scalar::I32(1), BinOp::Sub, true)?; // 0-based
    let ones = x.eng.fill(Scalar::F64(1.0), x.nrow(), 1);
    let mind = d.agg_row(AggOp::Min)?;

    // the whole E-step as one planned batch: three independent statements
    // the optimizer fuses back into a single scan of X
    let reqs = vec![
        PlanRequest::sink(x.groupby_row_sink(&labels, k, AggOp::Sum)?),
        PlanRequest::sink(ones.groupby_row_sink(&labels, k, AggOp::Sum)?),
        PlanRequest::sink(mind.agg_sink(AggOp::Sum)),
    ];
    let rs = x.eng.plan_batch(&reqs)?;
    let sums = rs[0].clone().sink().mat().to_row_major_f64(); // k×p row-major
    let counts: Vec<f64> = rs[1].clone().sink().mat().buf.to_f64_vec();
    let wcss = rs[2].clone().sink().scalar().as_f64();
    Ok((sums, counts, wcss))
}

/// One Lloyd iteration through the XLA artifact (full partitions) + native
/// tail steps, folded identically.
fn step_xla(
    x: &FmMatrix,
    svc: &crate::runtime::XlaService,
    name: &str,
    c: &HostMat,
    k: usize,
) -> Result<(Vec<f64>, Vec<f64>, f64)> {
    let d = super::dense_of(x)?;
    let p = d.ncol() as usize;
    let crm = c.to_row_major_f64();
    let mut sums = vec![0.0; k * p];
    let mut counts = vec![0.0; k];
    let mut wcss = 0.0;
    for i in 0..d.parts.n_parts() {
        if d.parts.is_full(i) {
            let (rows, rm) = super::partition_row_major(d, i)?;
            x.eng
                .metrics
                .xla_dispatches
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let out = svc.run(
                name,
                vec![
                    HostTensor::f64(vec![rows, p], rm),
                    HostTensor::f64(vec![k, p], crm.clone()),
                ],
            )?;
            // outputs: sums (k,p), counts (k), wcss (), assign (rows)
            for (a, b) in sums.iter_mut().zip(out[0].as_f64()?) {
                *a += b;
            }
            for (a, b) in counts.iter_mut().zip(out[1].as_f64()?) {
                *a += b;
            }
            wcss += out[2].as_f64()?[0];
        } else {
            let buf = d.partition_buf(i)?;
            let (s, cnt, w, _a) =
                super::steps::kmeans_step_native(&buf, d.parts.rows_in(i) as usize, p, c)?;
            for (a, b) in sums.iter_mut().zip(s) {
                *a += b;
            }
            for (a, b) in counts.iter_mut().zip(cnt) {
                *a += b;
            }
            wcss += w;
        }
    }
    Ok((sums, counts, wcss))
}

/// Final assignment of every point (one extra fused pass) — useful for
/// downstream consumers; returns an n×1 i32 matrix of labels in 0..k.
pub fn assign(x: &FmMatrix, c: &HostMat) -> Result<FmMatrix> {
    let p = x.ncol() as usize;
    let k = c.nrow;
    let mut ct2 = HostMat::zeros(p, k, crate::dtype::DType::F64);
    let mut c2 = HostMat::zeros(1, k, crate::dtype::DType::F64);
    for ci in 0..k {
        let mut s = 0.0;
        for j in 0..p {
            let v = c.get(ci, j).as_f64();
            ct2.set(j, ci, Scalar::F64(-2.0 * v));
            s += v * v;
        }
        c2.set(0, ci, Scalar::F64(s));
    }
    let d = x
        .inner_prod_small(&ct2, BinOp::Mul, AggOp::Sum)?
        .mapply_row(&c2, BinOp::Add)?;
    // ||x||² is constant per row: argmin unaffected — skip it
    d.which_min_row()?
        .mapply_scalar(Scalar::I32(1), BinOp::Sub, true)?
        .materialize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::fmr::Engine;

    #[test]
    fn kmeans_recovers_separated_clusters() {
        let e = Engine::new(EngineConfig {
            xla_dispatch: false,
            chunk_bytes: 1 << 20,
            target_part_bytes: 1 << 20,
            ..Default::default()
        })
        .unwrap();
        let (x, means) = crate::datasets::mix_gaussian(&e, 20_000, 4, 3, 12.0, 17, None).unwrap();
        let r = kmeans(&x, 3, 8, 1).unwrap();
        // WCSS must be monotone non-increasing
        for w in r.wcss.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-9), "wcss increased: {w:?}");
        }
        // every found centroid must be close to a true mean
        for ci in 0..3 {
            let best = (0..3)
                .map(|ti| {
                    (0..4)
                        .map(|j| {
                            let d = r.centroids.get(ci, j).as_f64() - means.get(ti, j).as_f64();
                            d * d
                        })
                        .sum::<f64>()
                })
                .fold(f64::INFINITY, f64::min);
            assert!(best < 1.0, "centroid {ci} too far: {best}");
        }
        // cluster sizes roughly balanced (hash assignment is uniform)
        for &s in &r.sizes {
            assert!(s > 20_000.0 / 3.0 * 0.7 && s < 20_000.0 / 3.0 * 1.3);
        }
    }

    #[test]
    fn assign_labels_match_centroid_proximity() {
        let e = Engine::new(EngineConfig {
            xla_dispatch: false,
            chunk_bytes: 1 << 20,
            target_part_bytes: 1 << 20,
            ..Default::default()
        })
        .unwrap();
        let (x, _means) = crate::datasets::mix_gaussian(&e, 5000, 3, 2, 10.0, 23, None).unwrap();
        let r = kmeans(&x, 2, 5, 2).unwrap();
        let labels = assign(&x, &r.centroids).unwrap().to_host().unwrap();
        // labels in range
        for i in 0..labels.nrow {
            let l = labels.get(i, 0).as_i64();
            assert!((0..2).contains(&l));
        }
    }
}
