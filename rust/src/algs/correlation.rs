//! Pair-wise Pearson correlation (paper §IV-A).
//!
//! Faithful to the paper's two-pass implementation ("the current
//! implementation of correlation requires an additional pass on the input
//! matrix to compute column-wise mean values" — the reason its EM curve in
//! Fig 9 sits below SVD's):
//!   pass 1 — column means (`fm.agg.col`);
//!   pass 2 — centered Gramian (`fm.inner.prod(t(X-mu), X-mu)`), with the
//!            centering fused into the Gramian scan.

use crate::error::Result;
use crate::fmr::FmMatrix;
use crate::matrix::HostMat;
use crate::runtime::HostTensor;
use crate::vudf::{AggOp, BinOp};

/// p×p Pearson correlation matrix (row-major) + the centered Gramian it
/// derives from.
#[derive(Clone, Debug)]
pub struct CorrelationResult {
    pub p: usize,
    /// row-major p×p correlation coefficients
    pub corr: Vec<f64>,
    /// row-major p×p centered Gramian (unnormalized covariance)
    pub centered_gramian: Vec<f64>,
    pub mean: Vec<f64>,
}

/// Two-pass Pearson correlation of a tall matrix.
pub fn correlation(x: &FmMatrix) -> Result<CorrelationResult> {
    let n = x.nrow();
    let p = x.ncol() as usize;

    // pass 1: column means
    let mu = x.col_means()?; // 1×p host
    let mu_v = mu.buf.to_f64_vec();

    // pass 2: centered Gramian
    let g = if let Some((svc, name)) = super::xla_candidate(x, "gramian_centered", 0) {
        centered_gramian_xla(x, &svc, &name, &mu_v)?
    } else {
        centered_gramian_genop(x, &mu)?
    };

    let mut corr = vec![0.0; p * p];
    for i in 0..p {
        for j in 0..p {
            let denom = (g[i * p + i] * g[j * p + j]).sqrt();
            corr[i * p + j] = if denom > 0.0 { g[i * p + j] / denom } else { 0.0 };
        }
    }
    let _ = n;
    Ok(CorrelationResult {
        p,
        corr,
        centered_gramian: g,
        mean: mu_v,
    })
}

/// GenOp pass 2: the centering (`fm.mapply.row(X, mu, sub)`) fuses into the
/// wide×tall inner product — X streams once.
fn centered_gramian_genop(x: &FmMatrix, mu: &HostMat) -> Result<Vec<f64>> {
    let xc = x.mapply_row(mu, BinOp::Sub)?;
    let g = xc.t().inner_prod_wide_tall(&xc, BinOp::Mul, AggOp::Sum)?;
    Ok(g.to_row_major_f64())
}

/// XLA pass 2: the gramian_centered artifact per full partition.
fn centered_gramian_xla(
    x: &FmMatrix,
    svc: &crate::runtime::XlaService,
    name: &str,
    mu: &[f64],
) -> Result<Vec<f64>> {
    let d = super::dense_of(x)?;
    let p = d.ncol() as usize;
    let mut acc = vec![0.0; p * p];
    for i in 0..d.parts.n_parts() {
        let part: Vec<f64> = if d.parts.is_full(i) {
            let (rows, rm) = super::partition_row_major(d, i)?;
            x.eng
                .metrics
                .xla_dispatches
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let out = svc.run(
                name,
                vec![
                    HostTensor::f64(vec![rows, p], rm),
                    HostTensor::f64(vec![p], mu.to_vec()),
                ],
            )?;
            out[0].as_f64()?.to_vec()
        } else {
            let buf = d.partition_buf(i)?;
            super::steps::gramian_centered_native(&buf, d.parts.rows_in(i) as usize, p, mu)?
        };
        for (a, b) in acc.iter_mut().zip(part) {
            *a += b;
        }
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::fmr::Engine;

    #[test]
    fn correlation_diag_is_one_and_symmetric() {
        let e = Engine::new(EngineConfig {
            xla_dispatch: false,
            chunk_bytes: 1 << 20,
            target_part_bytes: 1 << 20,
            ..Default::default()
        })
        .unwrap();
        let x = crate::datasets::spectral_like(&e, 8000, 4, 3, None).unwrap();
        let r = correlation(&x).unwrap();
        for i in 0..4 {
            assert!((r.corr[i * 4 + i] - 1.0).abs() < 1e-9);
            for j in 0..4 {
                assert!((r.corr[i * 4 + j] - r.corr[j * 4 + i]).abs() < 1e-9);
                assert!(r.corr[i * 4 + j].abs() <= 1.0 + 1e-12);
            }
        }
        // spectral_like columns are built from shared factors: expect some
        // non-trivial correlation
        let off: f64 = (0..4)
            .flat_map(|i| (0..4).map(move |j| (i, j)))
            .filter(|(i, j)| i != j)
            .map(|(i, j)| r.corr[i * 4 + j].abs())
            .fold(0.0, f64::max);
        assert!(off > 0.05, "columns unexpectedly uncorrelated: {off}");
    }

    #[test]
    fn perfectly_correlated_columns() {
        let e = Engine::new(EngineConfig {
            xla_dispatch: false,
            chunk_bytes: 1 << 20,
            target_part_bytes: 1 << 20,
            ..Default::default()
        })
        .unwrap();
        // col1 = 2*col0 + 3 -> corr = 1
        let x = crate::datasets::from_fn(&e, 5000, 2, None, |r, j| {
            let v = crate::exec::u64_to_unit_f64(crate::exec::splitmix64_at(1, r));
            if j == 0 {
                v
            } else {
                2.0 * v + 3.0
            }
        })
        .unwrap();
        let r = correlation(&x).unwrap();
        assert!((r.corr[1] - 1.0).abs() < 1e-9);
    }
}
