//! Native per-partition algorithm steps.
//!
//! These mirror the AOT artifacts' input/output contracts exactly
//! (python/compile/model.py): same shapes, same row-major output order, so
//! the algorithm drivers can mix XLA-dispatched full partitions with
//! native tail partitions and fold the outputs identically. They are also
//! the cross-check target for `rust/tests/golden.rs`.
//!
//! Inputs are col-major partition buffers straight from
//! [`crate::matrix::DenseData::partition_buf`].

use crate::error::{FmError, Result};
use crate::matrix::HostMat;
use crate::vudf::Buf;

/// Fused column statistics of one partition -> row-major (6, p):
/// `[min, max, sum, sumsq, sumabs, nnz]` per column (matches the Pallas
/// colstats kernel).
pub fn colstats_native(x: &Buf, rows: usize, p: usize) -> Result<Vec<f64>> {
    let xv = as_f64(x, rows * p)?;
    let mut out = vec![0.0; 6 * p];
    for j in 0..p {
        let col = &xv[j * rows..(j + 1) * rows];
        let (mut mn, mut mx, mut s, mut ss, mut sa, mut nnz) =
            (f64::INFINITY, f64::NEG_INFINITY, 0.0, 0.0, 0.0, 0.0);
        for &v in col {
            mn = mn.min(v);
            mx = mx.max(v);
            s += v;
            ss += v * v;
            sa += v.abs();
            nnz += (v != 0.0) as u8 as f64;
        }
        out[j] = mn;
        out[p + j] = mx;
        out[2 * p + j] = s;
        out[3 * p + j] = ss;
        out[4 * p + j] = sa;
        out[5 * p + j] = nnz;
    }
    Ok(out)
}

/// k-means partition step (matches the kmeans artifact):
/// returns (sums row-major (k,p), counts (k), wcss, assign (rows) 0-based).
pub fn kmeans_step_native(
    x: &Buf,
    rows: usize,
    p: usize,
    c: &HostMat,
) -> Result<(Vec<f64>, Vec<f64>, f64, Vec<i32>)> {
    let xv = as_f64(x, rows * p)?;
    let k = c.nrow;
    let crm = c.to_row_major_f64(); // (k, p)
    let c2: Vec<f64> = (0..k)
        .map(|ci| (0..p).map(|j| crm[ci * p + j] * crm[ci * p + j]).sum())
        .collect();
    let mut sums = vec![0.0; k * p];
    let mut counts = vec![0.0; k];
    let mut wcss = 0.0;
    let mut assign = vec![0i32; rows];
    for r in 0..rows {
        // x2 for this row
        let mut x2 = 0.0;
        for j in 0..p {
            let v = xv[j * rows + r];
            x2 += v * v;
        }
        let mut best = f64::INFINITY;
        let mut bi = 0usize;
        for ci in 0..k {
            let mut dot = 0.0;
            for j in 0..p {
                dot += xv[j * rows + r] * crm[ci * p + j];
            }
            let d = x2 - 2.0 * dot + c2[ci];
            if d < best {
                best = d;
                bi = ci;
            }
        }
        assign[r] = bi as i32;
        counts[bi] += 1.0;
        wcss += best;
        for j in 0..p {
            sums[bi * p + j] += xv[j * rows + r];
        }
    }
    Ok((sums, counts, wcss, assign))
}

/// One-pass Gramian partition step: (xtx row-major (p,p), colsums (p)).
pub fn gramian_native(x: &Buf, rows: usize, p: usize) -> Result<(Vec<f64>, Vec<f64>)> {
    let xv = as_f64(x, rows * p)?;
    let mut xtx = vec![0.0; p * p];
    let mut cs = vec![0.0; p];
    for i in 0..p {
        let ci = &xv[i * rows..(i + 1) * rows];
        cs[i] = ci.iter().sum();
        for j in i..p {
            let cj = &xv[j * rows..(j + 1) * rows];
            let mut dot = 0.0;
            for r in 0..rows {
                dot += ci[r] * cj[r];
            }
            xtx[i * p + j] = dot;
            xtx[j * p + i] = dot;
        }
    }
    Ok((xtx, cs))
}

/// Centered Gramian partition step: xtx_c row-major (p,p).
pub fn gramian_centered_native(x: &Buf, rows: usize, p: usize, mu: &[f64]) -> Result<Vec<f64>> {
    let xv = as_f64(x, rows * p)?;
    let mut xtx = vec![0.0; p * p];
    for i in 0..p {
        let ci = &xv[i * rows..(i + 1) * rows];
        for j in i..p {
            let cj = &xv[j * rows..(j + 1) * rows];
            let mut dot = 0.0;
            for r in 0..rows {
                dot += (ci[r] - mu[i]) * (cj[r] - mu[j]);
            }
            xtx[i * p + j] = dot;
            xtx[j * p + i] = dot;
        }
    }
    Ok(xtx)
}

/// GMM E-step partition stats (matches the gmm artifact):
/// (Nk (k), Sk row-major (k,p), SSk row-major (k,p,p), loglik).
#[allow(clippy::too_many_arguments)]
pub fn gmm_estep_native(
    x: &Buf,
    rows: usize,
    p: usize,
    means_rm: &[f64],  // (k, p)
    prec_rm: &[f64],   // (k, p, p)
    logdet: &[f64],    // (k)
    logw: &[f64],      // (k)
) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>, f64)> {
    let xv = as_f64(x, rows * p)?;
    let k = logw.len();
    // pmu_k = P_k mu_k ; mupmu_k = mu_k^T P_k mu_k
    let mut pmu = vec![0.0; k * p];
    let mut mupmu = vec![0.0; k];
    for c in 0..k {
        for i in 0..p {
            let mut s = 0.0;
            for j in 0..p {
                s += prec_rm[c * p * p + i * p + j] * means_rm[c * p + j];
            }
            pmu[c * p + i] = s;
        }
        mupmu[c] = (0..p).map(|i| pmu[c * p + i] * means_rm[c * p + i]).sum();
    }
    let cst = -0.5 * p as f64 * (2.0 * std::f64::consts::PI).ln();

    let mut nk = vec![0.0; k];
    let mut sk = vec![0.0; k * p];
    let mut ssk = vec![0.0; k * p * p];
    let mut ll = 0.0;
    let mut xrow = vec![0.0; p];
    let mut logp = vec![0.0; k];
    for r in 0..rows {
        for j in 0..p {
            xrow[j] = xv[j * rows + r];
        }
        for c in 0..k {
            // x P x^T
            let mut xpx = 0.0;
            for i in 0..p {
                let mut s = 0.0;
                for j in 0..p {
                    s += prec_rm[c * p * p + i * p + j] * xrow[j];
                }
                xpx += xrow[i] * s;
            }
            let xpm: f64 = (0..p).map(|i| xrow[i] * pmu[c * p + i]).sum();
            let maha = xpx - 2.0 * xpm + mupmu[c];
            logp[c] = logw[c] + 0.5 * logdet[c] - 0.5 * maha + cst;
        }
        let m = logp.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let se: f64 = logp.iter().map(|v| (v - m).exp()).sum();
        let lse = m + se.ln();
        ll += lse;
        for c in 0..k {
            let resp = (logp[c] - lse).exp();
            nk[c] += resp;
            for i in 0..p {
                sk[c * p + i] += resp * xrow[i];
                for j in 0..p {
                    ssk[c * p * p + i * p + j] += resp * xrow[i] * xrow[j];
                }
            }
        }
    }
    Ok((nk, sk, ssk, ll))
}

fn as_f64(x: &Buf, want: usize) -> Result<&[f64]> {
    match x {
        Buf::F64(v) if v.len() == want => Ok(v),
        Buf::F64(v) => Err(FmError::Shape(format!(
            "partition buffer has {} elements, want {want}",
            v.len()
        ))),
        other => Err(FmError::DType(format!(
            "native step requires f64 partitions, got {}",
            other.dtype()
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::DType;

    fn colmajor(rows: usize, p: usize, f: impl Fn(usize, usize) -> f64) -> Buf {
        let mut b = Buf::alloc(DType::F64, rows * p);
        for j in 0..p {
            for r in 0..rows {
                b.set(j * rows + r, crate::dtype::Scalar::F64(f(r, j)));
            }
        }
        b
    }

    #[test]
    fn colstats_simple() {
        let x = colmajor(4, 2, |r, j| (r as f64 + 1.0) * if j == 0 { 1.0 } else { -1.0 });
        let s = colstats_native(&x, 4, 2).unwrap();
        assert_eq!(s[0], 1.0); // min col0
        assert_eq!(s[1], -4.0); // min col1
        assert_eq!(s[2 * 2], 10.0); // sum col0
        assert_eq!(s[3 * 2 + 1], 30.0); // sumsq col1
        assert_eq!(s[5 * 2], 4.0); // nnz col0
    }

    #[test]
    fn kmeans_step_two_obvious_clusters() {
        // points at 0 and at 10; centroids 0 and 10
        let x = colmajor(4, 1, |r, _| if r < 2 { 0.0 } else { 10.0 });
        let c = HostMat::from_rows_f64(&[vec![0.0], vec![10.0]]);
        let (sums, counts, wcss, assign) = kmeans_step_native(&x, 4, 1, &c).unwrap();
        assert_eq!(counts, vec![2.0, 2.0]);
        assert_eq!(sums, vec![0.0, 20.0]);
        assert_eq!(wcss, 0.0);
        assert_eq!(assign, vec![0, 0, 1, 1]);
    }

    #[test]
    fn gramian_matches_manual() {
        let x = colmajor(3, 2, |r, j| (r + j) as f64);
        let (xtx, cs) = gramian_native(&x, 3, 2).unwrap();
        // col0 = [0,1,2], col1 = [1,2,3]
        assert_eq!(cs, vec![3.0, 6.0]);
        assert_eq!(xtx, vec![5.0, 8.0, 8.0, 14.0]);
        let mu = [1.0, 2.0];
        let xc = gramian_centered_native(&x, 3, 2, &mu).unwrap();
        assert_eq!(xc, vec![2.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn gmm_estep_responsibilities_sum_to_rows() {
        let rows = 8;
        let p = 2;
        let k = 2;
        let x = colmajor(rows, p, |r, j| (r % 3) as f64 + j as f64);
        let means = vec![0.0, 0.0, 2.0, 2.0];
        let mut prec = vec![0.0; k * p * p];
        for c in 0..k {
            prec[c * 4] = 1.0;
            prec[c * 4 + 3] = 1.0;
        }
        let logdet = vec![0.0, 0.0];
        let logw = vec![(0.5f64).ln(), (0.5f64).ln()];
        let (nk, sk, ssk, ll) =
            gmm_estep_native(&x, rows, p, &means, &prec, &logdet, &logw).unwrap();
        assert!((nk.iter().sum::<f64>() - rows as f64).abs() < 1e-9);
        assert_eq!(sk.len(), k * p);
        assert_eq!(ssk.len(), k * p * p);
        assert!(ll.is_finite());
    }
}
