//! The paper's evaluation algorithms (§IV-A), written against the `fmr`
//! R-like interface, with an optional AOT-XLA fast path per partition.
//!
//! Each algorithm has two execution paths that produce identical results:
//!
//! 1. **GenOp path** — the algorithm exactly as the paper's R code would
//!    express it: lazy GenOps fused into one streaming pass per logical
//!    pass over the data, parallelized by the engine. Used always for
//!    correctness, and exclusively when `xla_dispatch` is off.
//! 2. **XLA path** — when the data matrix is dense f64 with the canonical
//!    partitioning and `artifacts/manifest.json` has a matching module,
//!    each *full* partition's step runs on the AOT-compiled XLA executable
//!    (the role BLAS plays in the paper); tail partitions use the native
//!    [`steps`] functions with the identical contract.

pub mod correlation;
pub mod gmm;
pub mod kmeans;
pub mod linalg;
pub mod logistic;
pub mod pagerank;
pub mod steps;
pub mod summary;
pub mod svd;

pub use correlation::correlation;
pub use gmm::{gmm, GmmResult};
pub use kmeans::{kmeans, KmeansResult};
pub use logistic::{logistic, LogisticResult};
pub use pagerank::{pagerank, PagerankResult};
pub use summary::{summary, SummaryResult};
pub use svd::{svd, SvdResult};

use crate::error::{FmError, Result};
use crate::fmr::FmMatrix;
use crate::matrix::{DenseData, MatrixData};
use crate::runtime::XlaService;

/// If `x` is eligible for artifact dispatch of `kind` (with cluster count
/// `k`; 0 when not applicable), return the service and artifact name.
pub(crate) fn xla_candidate(x: &FmMatrix, kind: &str, k: u64) -> Option<(XlaService, String)> {
    if !x.eng.config.xla_dispatch || x.m.transposed {
        return None;
    }
    if !x.eng.config.xla_kinds.iter().any(|k| k == kind || k == "all") {
        return None;
    }
    let d = dense_of(x).ok()?;
    if d.dtype != crate::dtype::DType::F64 {
        return None;
    }
    if d.parts.io_rows != crate::matrix::io_rows_for(d.ncol()) {
        return None;
    }
    let svc = x.eng.xla()?.clone();
    let name = svc.lookup(kind, d.ncol(), k)?.name.clone();
    Some((svc, name))
}

/// Dense backing of a (materialized) matrix.
pub(crate) fn dense_of(x: &FmMatrix) -> Result<&DenseData> {
    match &*x.m.data {
        MatrixData::Dense(d) => Ok(d),
        _ => Err(FmError::Shape(
            "algorithm input must be materialized; call .materialize()".into(),
        )),
    }
}

/// Partition `i` of a dense f64 matrix as a row-major vector (the layout
/// XLA literals use). Returns (rows, data).
pub(crate) fn partition_row_major(d: &DenseData, i: usize) -> Result<(usize, Vec<f64>)> {
    let buf = d.partition_buf(i)?;
    let rows = d.parts.rows_in(i) as usize;
    let p = d.ncol() as usize;
    let v = match &buf {
        crate::vudf::Buf::F64(v) => v,
        _ => return Err(FmError::DType("expected f64 partition".into())),
    };
    let mut rm = vec![0.0f64; rows * p];
    for j in 0..p {
        let col = &v[j * rows..(j + 1) * rows];
        for r in 0..rows {
            rm[r * p + j] = col[r];
        }
    }
    Ok((rows, rm))
}
