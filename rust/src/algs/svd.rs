//! Singular value decomposition of tall matrices (paper §IV-A): compute
//! the Gramian `t(A) %*% A` (one pass), then the eigendecomposition of the
//! small p×p Gramian (host-side cyclic Jacobi) to derive singular values
//! and right singular vectors; optionally one more pass reconstructs the
//! left singular vectors `U = A V Σ^{-1}` via `fm.inner.prod`.

use crate::error::Result;
use crate::fmr::FmMatrix;
use crate::matrix::HostMat;
use crate::runtime::HostTensor;
use crate::vudf::{AggOp, BinOp};

/// Truncated SVD result.
#[derive(Clone, Debug)]
pub struct SvdResult {
    /// Singular values, descending (length nv).
    pub sigma: Vec<f64>,
    /// Right singular vectors, row-major p×nv.
    pub v: Vec<f64>,
    pub p: usize,
    pub nv: usize,
}

/// Compute the top `nv` singular values/right vectors of a tall matrix.
pub fn svd(x: &FmMatrix, nv: usize) -> Result<SvdResult> {
    let p = x.ncol() as usize;
    let nv = nv.min(p);

    // one pass: Gramian
    let g: Vec<f64> = if let Some((svc, name)) = super::xla_candidate(x, "gramian", 0) {
        gramian_xla(x, &svc, &name)?
    } else {
        x.crossprod(x)?.to_row_major_f64()
    };

    // host: eigendecomposition of the p×p Gramian
    let (vals, vecs) = super::linalg::jacobi_eigen(&g, p, 100)?;
    let sigma: Vec<f64> = vals.iter().take(nv).map(|l| l.max(0.0).sqrt()).collect();
    let mut v = vec![0.0; p * nv];
    for r in 0..p {
        for c in 0..nv {
            v[r * nv + c] = vecs[r * p + c];
        }
    }
    Ok(SvdResult { sigma, v, p, nv })
}

/// Optional extra pass: left singular vectors `U = A V Σ^{-1}` (n×nv,
/// materialized through the engine).
pub fn left_vectors(x: &FmMatrix, s: &SvdResult) -> Result<FmMatrix> {
    let mut w = HostMat::zeros(s.p, s.nv, crate::dtype::DType::F64);
    for r in 0..s.p {
        for c in 0..s.nv {
            let scale = if s.sigma[c] > 1e-300 { 1.0 / s.sigma[c] } else { 0.0 };
            w.set(
                r,
                c,
                crate::dtype::Scalar::F64(s.v[r * s.nv + c] * scale),
            );
        }
    }
    x.inner_prod_small(&w, BinOp::Mul, AggOp::Sum)?.materialize()
}

fn gramian_xla(
    x: &FmMatrix,
    svc: &crate::runtime::XlaService,
    name: &str,
) -> Result<Vec<f64>> {
    let d = super::dense_of(x)?;
    let p = d.ncol() as usize;
    let mut acc = vec![0.0; p * p];
    for i in 0..d.parts.n_parts() {
        let part: Vec<f64> = if d.parts.is_full(i) {
            let (rows, rm) = super::partition_row_major(d, i)?;
            x.eng
                .metrics
                .xla_dispatches
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let out = svc.run(name, vec![HostTensor::f64(vec![rows, p], rm)])?;
            out[0].as_f64()?.to_vec()
        } else {
            let buf = d.partition_buf(i)?;
            super::steps::gramian_native(&buf, d.parts.rows_in(i) as usize, p)?.0
        };
        for (a, b) in acc.iter_mut().zip(part) {
            *a += b;
        }
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::fmr::Engine;

    fn eng() -> std::sync::Arc<Engine> {
        Engine::new(EngineConfig {
            xla_dispatch: false,
            chunk_bytes: 1 << 20,
            target_part_bytes: 1 << 20,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn svd_of_orthogonal_columns() {
        let e = eng();
        // two orthogonal columns with known norms: sigma = norms
        let x = crate::datasets::from_fn(&e, 4096, 2, None, |r, j| {
            let s = if r % 2 == 0 { 1.0 } else { -1.0 };
            if j == 0 {
                2.0 * s
            } else if r % 4 < 2 {
                3.0
            } else {
                -3.0
            }
        })
        .unwrap();
        let s = svd(&x, 2).unwrap();
        // column norms: 2*sqrt(n), 3*sqrt(n)
        let n = 4096f64;
        assert!((s.sigma[0] - 3.0 * n.sqrt()).abs() / s.sigma[0] < 1e-9);
        assert!((s.sigma[1] - 2.0 * n.sqrt()).abs() / s.sigma[1] < 1e-9);
    }

    #[test]
    fn singular_values_match_frobenius() {
        let e = eng();
        let x = crate::datasets::uniform(&e, 5000, 6, -1.0, 1.0, 3, None).unwrap();
        let s = svd(&x, 6).unwrap();
        // sum sigma_i^2 == ||X||_F^2
        let fro = x.sq().unwrap().sum().unwrap();
        let ss: f64 = s.sigma.iter().map(|v| v * v).sum();
        assert!((fro - ss).abs() / fro < 1e-9);
        // descending
        for i in 1..6 {
            assert!(s.sigma[i - 1] >= s.sigma[i] - 1e-12);
        }
    }

    #[test]
    fn left_vectors_are_orthonormal() {
        let e = eng();
        let x = crate::datasets::uniform(&e, 3000, 4, -1.0, 1.0, 8, None).unwrap();
        let s = svd(&x, 3).unwrap();
        let u = left_vectors(&x, &s).unwrap();
        // t(U) U = I (3x3)
        let g = u.crossprod(&u).unwrap().to_row_major_f64();
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((g[i * 3 + j] - want).abs() < 1e-8, "{i},{j}: {}", g[i * 3 + j]);
            }
        }
    }
}
