//! `flashmatrix` — the launcher.
//!
//! ```text
//! flashmatrix run <alg>      [--n N] [--p P] [--k K] [--iters I] [--em]
//!                            [--threads T] [--no-xla] [--ssd-bps B]
//! flashmatrix bench <fig>    fig6a|fig6b|fig7|fig8|fig9|fig10|fig11|fig12|table4|sparse|writeback|all
//! flashmatrix artifacts      # list the AOT artifact manifest
//! flashmatrix info           # engine / environment summary
//! ```
//!
//! `run` executes one algorithm end-to-end on a generated dataset and
//! prints the result + engine metrics; `bench` regenerates a paper figure
//! (see DESIGN.md experiment index; results recorded in EXPERIMENTS.md).

use std::sync::Arc;

use flashmatrix::error::Result;
use flashmatrix::fmr::Engine;
use flashmatrix::harness::{self, Alg, Mode, Scale};
use flashmatrix::util::cli::Args;
use flashmatrix::{datasets, EngineConfig, StorageKind};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn scale_from(args: &Args) -> Scale {
    let mut s = Scale::default();
    s.n = args.u64_or("n", s.n);
    s.n_small = args.u64_or("n-small", s.n_small);
    s.iters = args.usize_or("iters", s.iters);
    s.threads = args.usize_or("threads", s.threads);
    s.ssd_bps = args.u64_or("ssd-bps", s.ssd_bps);
    s.artifacts_dir = args.get_or("artifacts", "artifacts").to_string();
    s.data_dir = args.get_or("data-dir", "data").to_string();
    s.xla = !args.has("no-xla");
    s
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(args),
        Some("bench") => cmd_bench(args),
        Some("artifacts") => cmd_artifacts(args),
        Some("info") => cmd_info(args),
        _ => {
            eprintln!(
                "usage: flashmatrix <run|bench|artifacts|info> [...]\n\
                 see `rust/src/main.rs` docs or README.md"
            );
            Ok(())
        }
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let s = scale_from(args);
    let alg = match args.positional.first().map(|s| s.as_str()) {
        Some("summary") => Alg::Summary,
        Some("correlation") => Alg::Correlation,
        Some("svd") => Alg::Svd,
        Some("kmeans") => Alg::Kmeans,
        Some("gmm") => Alg::Gmm,
        other => {
            return Err(flashmatrix::FmError::Config(format!(
                "unknown algorithm {other:?}; use summary|correlation|svd|kmeans|gmm"
            )))
        }
    };
    let mode = if args.has("em") { Mode::FmEm } else { Mode::FmIm };
    let p = args.u64_or("p", 32);
    let k = args.usize_or("k", 10);
    let eng = harness::engine_for(&s, mode, s.threads)?;
    println!(
        "flashmatrix run {} [{}] n={} p={} k={} iters={} threads={} xla={}",
        alg.label(),
        mode.label(),
        s.n,
        p,
        k,
        s.iters,
        s.threads,
        s.xla
    );
    let t0 = std::time::Instant::now();
    let (x, _means) = datasets::mix_gaussian(&eng, s.n, p, k as u64, 6.0, 42, None)?;
    println!("dataset generated in {:.2}s", t0.elapsed().as_secs_f64());
    eng.metrics.reset();
    let secs = harness::run_alg(&x, alg, k, s.iters)?;
    let m = eng.metrics.snapshot();
    println!("{} finished in {:.3}s", alg.label(), secs);
    println!(
        "metrics: read={:.2}GB write={:.2}GB reads={} peak_mem={:.2}GB \
         xla_parts={} native_parts={} chunks(alloc/reuse)={}/{}",
        m.io_read_bytes as f64 / 1e9,
        m.io_write_bytes as f64 / 1e9,
        m.io_read_reqs,
        m.mem_peak as f64 / 1e9,
        m.xla_dispatches,
        m.native_partitions,
        m.chunks_allocated,
        m.chunks_recycled,
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let s = scale_from(args);
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let max_threads = args.usize_or("max-threads", (s.threads * 2).max(2));
    let ps: Vec<u64> = args
        .get("ps")
        .map(|v| v.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|| vec![8, 16, 32, 64, 128, 256, 512]);
    let ks: Vec<usize> = args
        .get("ks")
        .map(|v| v.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|| vec![2, 4, 8, 16, 32, 64]);

    let mut tables = Vec::new();
    match which {
        "fig6a" => tables.push(harness::fig6a(&s)?),
        "fig6b" => tables.push(harness::fig6b(&s)?),
        "fig7" => tables.push(harness::fig7(&s)?),
        "fig8" => tables.push(harness::fig8(&s, max_threads)?),
        "fig9" => tables.push(harness::fig9(&s, &ps)?),
        "fig10" => tables.push(harness::fig10(&s, &ks)?),
        "fig11" => {
            tables.push(harness::fig11(&s, true)?);
            tables.push(harness::fig11(&s, false)?);
        }
        "fig12" => tables.push(harness::fig12(&s)?),
        "table4" => tables.push(harness::table4(&s)?),
        "sparse" => tables.push(harness::sparse_workloads(&s)?),
        "writeback" => tables.push(harness::writeback_overlap(&s)?),
        "all" => {
            tables.push(harness::fig6a(&s)?);
            tables.push(harness::fig6b(&s)?);
            tables.push(harness::fig7(&s)?);
            tables.push(harness::fig8(&s, max_threads)?);
            tables.push(harness::fig9(&s, &ps)?);
            tables.push(harness::fig10(&s, &ks)?);
            tables.push(harness::fig11(&s, true)?);
            tables.push(harness::fig11(&s, false)?);
            tables.push(harness::fig12(&s)?);
            tables.push(harness::table4(&s)?);
            tables.push(harness::sparse_workloads(&s)?);
            tables.push(harness::writeback_overlap(&s)?);
        }
        other => {
            return Err(flashmatrix::FmError::Config(format!(
                "unknown figure '{other}'"
            )))
        }
    }
    for t in &tables {
        t.print();
    }
    if let Some(out) = args.get("json") {
        let arr = flashmatrix::util::json::Json::Arr(tables.iter().map(|t| t.to_json()).collect());
        std::fs::write(out, arr.to_string())?;
        println!("\nwrote {out}");
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let metas = flashmatrix::runtime::manifest::load_manifest(std::path::Path::new(dir))?;
    println!("{} artifacts in {dir}:", metas.len());
    for m in metas {
        println!(
            "  {:28} kind={:16} rows={:6} p={:3} k={:2} ins={} outs={}",
            m.name,
            m.kind,
            m.rows,
            m.p,
            m.k,
            m.inputs.len(),
            m.outputs.len()
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let s = scale_from(args);
    let cfg = EngineConfig::default();
    let eng: Arc<Engine> = Engine::new(cfg)?;
    println!("flashmatrix — FlashR/FlashMatrix reproduction");
    println!("  cores: {}", s.threads);
    println!("  chunk: {} MiB", eng.config.chunk_bytes >> 20);
    println!(
        "  io partition target: {} MiB; cpu partition: {} KiB",
        eng.config.target_part_bytes >> 20,
        eng.config.cpu_part_bytes >> 10
    );
    println!(
        "  storage default: {:?}; data dir: {}",
        if eng.config.storage == StorageKind::InMem {
            "in-memory"
        } else {
            "external"
        },
        eng.config.data_dir.display()
    );
    match eng.xla() {
        Some(svc) => println!("  xla: {} artifacts available", svc.artifacts().len()),
        None => println!("  xla: unavailable (run `make artifacts`)"),
    }
    Ok(())
}
