//! Bench: multi-tenant serving — three tenants (k-means, PageRank, IRLS)
//! interleaved as [`flashmatrix::Session`]s over ONE shared engine vs the
//! same three workloads serialized on the root engine (the pre-session
//! one-pass-at-a-time regime). External memory, shared partition cache,
//! deterministic SSD throttle, `threads = 1` per tenant so each
//! workload's fold order is fixed and the only variable is the
//! interleaving itself.
//!
//! Acceptance (gated by CI):
//! * every tenant's result is **bit-identical** to its serialized run —
//!   concurrency must be invisible to results;
//! * aggregate wall time interleaved is STRICTLY below serialized — the
//!   sessions really overlap (one tenant's I/O waits hide another's
//!   compute) instead of convoying on a cache-global barrier;
//! * cross-tenant evictions stay zero: every tenant's working set fits
//!   its fair share, so no tenant's residency is sacrificed to another's
//!   streaming (the isolation half of the fair-share policy).
//!
//! Run: `cargo bench --bench multitenant -- [--json-dir DIR]`. Emits
//! `BENCH_multitenant.json` for the CI gate.

use std::sync::Arc;
use std::time::Instant;

use flashmatrix::algs;
use flashmatrix::config::{EngineConfig, StorageKind, ThrottleConfig};
use flashmatrix::datasets;
use flashmatrix::fmr::{Engine, Session};
use flashmatrix::harness::BenchReport;
use flashmatrix::metrics::MetricsSnapshot;
use flashmatrix::util::bench::{bench_args, Table};

const SSD_BPS: u64 = 512 << 20;
/// Shared cache: comfortably above the sum of the three tenants' working
/// sets, so evictions — and in particular cross-tenant evictions — are
/// not forced by capacity and the isolation check is deterministic.
const CACHE_BYTES: usize = 24 << 20;
/// Per-tenant fair share: each workload below is sized to stay inside it.
const SESSION_SHARE: usize = 8 << 20;

fn root_engine(dir: &std::path::Path) -> Arc<Engine> {
    Engine::new(EngineConfig {
        storage: StorageKind::External,
        data_dir: dir.to_path_buf(),
        em_cache_bytes: CACHE_BYTES,
        prefetch_depth: 2,
        throttle: Some(ThrottleConfig {
            read_bytes_per_sec: SSD_BPS,
            write_bytes_per_sec: SSD_BPS,
        }),
        threads: 1, // bit-exact folds: interleaving is the only variable
        xla_dispatch: false,
        ..EngineConfig::default()
    })
    .expect("engine")
}

fn session_config(dir: &std::path::Path) -> EngineConfig {
    EngineConfig {
        storage: StorageKind::External,
        data_dir: dir.to_path_buf(),
        threads: 1,
        xla_dispatch: false,
        session_mem_bytes: SESSION_SHARE,
        ..EngineConfig::default()
    }
}

// -- the three tenant workloads (each builds its own data, then fits) -------

fn kmeans(eng: &Arc<Engine>) -> Vec<f64> {
    let (x, _) = datasets::mix_gaussian(eng, 100_000, 6, 3, 8.0, 3, None).expect("x");
    let km = algs::kmeans(&x, 3, 5, 1).expect("kmeans");
    let mut fp = km.wcss.clone();
    fp.extend(km.centroids.buf.to_f64_vec());
    fp.extend(km.sizes.clone());
    fp
}

fn pagerank(eng: &Arc<Engine>) -> Vec<f64> {
    let (g, dangling) = datasets::pagerank_graph(eng, 1 << 14, 8, 99, None).expect("graph");
    let pr = algs::pagerank(&g, &dangling, 0.85, 10, 0.0).expect("pagerank");
    let mut fp = pr.ranks.clone();
    fp.extend(pr.deltas);
    fp
}

fn irls(eng: &Arc<Engine>) -> Vec<f64> {
    let x = datasets::uniform(eng, 120_000, 6, -1.0, 1.0, 21, None).expect("x");
    let y = datasets::logistic_labels(&x, &[1.0, -0.5, 0.25, -1.5, 0.75, 0.0], 22).expect("y");
    let fit = algs::logistic(&x, &y, 5, 1e-8).expect("irls");
    let mut fp = fit.beta.clone();
    fp.extend(fit.deviances);
    fp
}

const TENANTS: [(&str, fn(&Arc<Engine>) -> Vec<f64>); 3] =
    [("kmeans", kmeans), ("pagerank", pagerank), ("irls", irls)];

fn main() {
    let args = bench_args();
    let json_dir = args.get_or("json-dir", ".").to_string();

    let mut t = Table::new(format!(
        "Multi-tenant serving: kmeans + PageRank + IRLS, 3 sessions over a \
         {} MiB shared cache ({} MiB share each), FM-EM, SSD {} MiB/s, \
         1 thread/tenant",
        CACHE_BYTES >> 20,
        SESSION_SHARE >> 20,
        SSD_BPS >> 20
    ));
    let mut report = BenchReport::new("multitenant");

    // -- serialized baseline: one tenant at a time on the root engine ------
    let ser_dir = std::env::temp_dir().join(format!("fm-mt-serial-{}", std::process::id()));
    std::fs::create_dir_all(&ser_dir).expect("bench data dir");
    let (serial_fps, serial_secs, serial_m) = {
        let root = root_engine(&ser_dir);
        let t0 = Instant::now();
        let fps: Vec<Vec<f64>> = TENANTS.iter().map(|(_, f)| f(&root)).collect();
        (fps, t0.elapsed().as_secs_f64(), root.metrics.snapshot())
    };
    let _ = std::fs::remove_dir_all(&ser_dir);
    t.add_with(
        "serialized total",
        serial_secs,
        "s",
        vec![
            ("passes".into(), serial_m.passes_run as f64),
            ("read_gb".into(), serial_m.io_read_bytes as f64 / 1e9),
        ],
    );

    // -- interleaved: one session per tenant, all three at once ------------
    let int_dir = std::env::temp_dir().join(format!("fm-mt-inter-{}", std::process::id()));
    std::fs::create_dir_all(&int_dir).expect("bench data dir");
    let root = root_engine(&int_dir);
    let sessions: Vec<Session> = TENANTS
        .iter()
        .map(|_| Session::open(&root, session_config(&int_dir)).expect("session"))
        .collect();
    let t0 = Instant::now();
    let mut inter_fps: Vec<Option<Vec<f64>>> = vec![None; TENANTS.len()];
    std::thread::scope(|s| {
        let handles: Vec<_> = TENANTS
            .iter()
            .zip(&sessions)
            .map(|((_, f), sess)| {
                let eng = Arc::clone(sess.engine());
                s.spawn(move || f(&eng))
            })
            .collect();
        for (slot, h) in inter_fps.iter_mut().zip(handles) {
            *slot = Some(h.join().expect("tenant panicked"));
        }
    });
    let inter_secs = t0.elapsed().as_secs_f64();
    let tenant_ms: Vec<MetricsSnapshot> =
        sessions.iter().map(|s| s.metrics().snapshot()).collect();

    let mut cross_total = 0u64;
    for ((name, _), m) in TENANTS.iter().zip(&tenant_ms) {
        t.add_with(
            format!("tenant {name}"),
            0.0,
            "s",
            vec![
                ("hits".into(), m.cache_hits as f64),
                ("misses".into(), m.cache_misses as f64),
                ("cross_evictions".into(), m.cache_cross_evictions as f64),
                ("passes".into(), m.passes_run as f64),
            ],
        );
        cross_total += m.cache_cross_evictions;
    }
    t.add_with(
        "interleaved total",
        inter_secs,
        "s",
        vec![
            ("sessions".into(), sessions.len() as f64),
            ("cross_evictions".into(), cross_total as f64),
        ],
    );
    drop(sessions);
    drop(root);
    let _ = std::fs::remove_dir_all(&int_dir);

    // -- acceptance ---------------------------------------------------------
    let mut ok = true;
    for (((name, _), a), b) in TENANTS.iter().zip(&serial_fps).zip(&inter_fps) {
        let b = b.as_ref().expect("joined above");
        let identical =
            a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits());
        println!(
            "{name}: serialized vs interleaved {}",
            if identical {
                "PASS: bit-identical"
            } else {
                "FAIL: diverged"
            }
        );
        report.add_check(format!("bit-identical: {name}"), identical);
        ok &= identical;
    }
    let faster = inter_secs < serial_secs;
    println!(
        "aggregate: serialized {serial_secs:.3}s vs interleaved {inter_secs:.3}s ({})",
        if faster { "PASS" } else { "FAIL" }
    );
    report.add_check("aggregate-faster-than-serialized", faster);
    let bounded = cross_total == 0;
    println!(
        "cross-tenant evictions: {cross_total} ({})",
        if bounded { "PASS" } else { "FAIL" }
    );
    report.add_check("bounded-cross-tenant-evictions", bounded);
    ok &= faster && bounded;

    t.print();
    report.add_table(&t);
    report
        .write(std::path::Path::new(&json_dir))
        .expect("bench json");
    assert!(
        ok,
        "interleaved tenants must be faster in aggregate, bit-identical \
         per tenant, and isolated (no cross-tenant evictions)"
    );
}
