//! Bench: what the always-on fault-tolerance machinery costs when
//! nothing fails (PR 8's acceptance bound: <= 5% wall-time).
//!
//! The protections wired through [`flashmatrix::storage::FileStore`] —
//! per-partition CRC32 recorded on write and verified on cold reads,
//! plus the transient-retry loop around every positioned op — run on
//! every out-of-core pass whether or not a fault plan is active. Fault
//! *injection* is test-only, but this cost is production cost, so it is
//! gated: `protections on` must stay within 5% of `protections off` on a
//! throttled streaming workload. The bound is deterministic for the same
//! reason the write-back bench's is: wall-time is dominated by the
//! token-bucket SSD model, and the CRC slice-by-8 pass (GB/s-class) runs
//! while the bucket refills, so the checksum work hides behind the
//! modeled I/O exactly like compute does.
//!
//! A third, ungated row runs the same workload under a live transient
//! fault plan (the chaos suite's spec at bench scale): it records how
//! much absorbed faults cost and re-asserts the core robustness contract
//! — the target is bit-identical to the fault-free runs.
//!
//! Run: `cargo bench --bench fault_overhead -- [--iters N] [--reps N] [--json-dir DIR]`

use std::sync::Arc;
use std::time::Instant;

use flashmatrix::config::{EngineConfig, StorageKind, ThrottleConfig};
use flashmatrix::datasets;
use flashmatrix::fmr::{Engine, FmMatrix};
use flashmatrix::harness::BenchReport;
use flashmatrix::matrix::HostMat;
use flashmatrix::storage::FaultConfig;
use flashmatrix::util::bench::{bench_args, Table};

/// Symmetric budget, same geometry as `benches/writeback.rs`: 32 MiB of
/// reads + 32 MiB of writes per pass at 256 MiB/s each way.
const SSD_BPS: u64 = 256 << 20;
/// Far smaller than the matrix: every pass streams cold.
const CACHE_BYTES: usize = 8 << 20;
const ROWS: u64 = 1 << 19; // x 8 cols x 8 B = 32 MiB
const COLS: u64 = 8;

fn engine(
    label: &str,
    dir: &std::path::Path,
    checksums: bool,
    faults: Option<FaultConfig>,
) -> Arc<Engine> {
    Engine::new(EngineConfig {
        storage: StorageKind::External,
        data_dir: dir.join(label.replace(' ', "-")),
        em_cache_bytes: CACHE_BYTES,
        prefetch_depth: 0, // synchronous demand I/O: nothing hides the CRC cost for us
        writeback: false,
        io_checksums: checksums,
        fault_injection: faults,
        throttle: Some(ThrottleConfig {
            read_bytes_per_sec: SSD_BPS,
            write_bytes_per_sec: SSD_BPS,
        }),
        threads: 1, // bit-exact targets across configurations
        xla_dispatch: false,
        ..EngineConfig::default()
    })
    .expect("engine")
}

/// One timed measurement: `iters` map-materialize passes (read 32 MiB +
/// write 32 MiB each, flush barrier included). Returns the wall seconds
/// and the final target for the bit-exactness check (read back untimed).
fn run(eng: &Arc<Engine>, x: &FmMatrix, iters: usize) -> (f64, HostMat) {
    if let Some(c) = &eng.cache {
        c.clear(); // start cold: every pass pays its reads
    }
    eng.ssd.drain_bursts();
    let mut last = None;
    let t0 = Instant::now();
    for _ in 0..iters {
        last = Some(x.sq().and_then(|y| y.materialize()).expect("map pass"));
    }
    let secs = t0.elapsed().as_secs_f64();
    let host = last.expect("at least one iter").to_host().expect("readback");
    (secs, host)
}

/// Median of `reps` measurements on one engine.
fn median_run(eng: &Arc<Engine>, iters: usize, reps: usize) -> (f64, HostMat) {
    let x = datasets::uniform(eng, ROWS, COLS, -1.0, 1.0, 7, None).expect("dataset");
    let mut secs = Vec::with_capacity(reps);
    let mut host = None;
    for _ in 0..reps {
        let (s, h) = run(eng, &x, iters);
        secs.push(s);
        host = Some(h);
    }
    secs.sort_by(f64::total_cmp);
    (secs[reps / 2], host.expect("at least one rep"))
}

fn main() {
    let args = bench_args();
    let iters = args.usize_or("iters", 3);
    let reps = args.usize_or("reps", 3);
    let json_dir = args.get_or("json-dir", ".").to_string();
    let dir = std::env::temp_dir().join(format!("fm-fault-overhead-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench data dir");

    // The chaos suite's transient spec at bench scale: every fault heals
    // within one retry / one checksum re-read, so results cannot move.
    let transient =
        FaultConfig::parse("seed=3201,eio=0.1,short=0.05,torn=0.05,bitflip=0.05,max_duration=1")
            .expect("transient spec");

    let mut t = Table::new(format!(
        "fault-tolerance overhead: {iters} sq() materialize passes x {reps} reps over \
         {} MiB EM (cache {} MiB, SSD {} MiB/s each way)",
        (ROWS * COLS * 8) >> 20,
        CACHE_BYTES >> 20,
        SSD_BPS >> 20
    ));

    let configs: [(&str, bool, Option<FaultConfig>); 3] = [
        ("protections-off", false, None),
        ("protections-on", true, None),
        ("faults-absorbed", true, Some(transient)),
    ];
    let mut medians = Vec::new();
    let mut targets: Vec<HostMat> = Vec::new();
    for (label, checksums, faults) in configs {
        let eng = engine(label, &dir, checksums, faults);
        eng.metrics.reset();
        let (secs, host) = median_run(&eng, iters, reps);
        let m = eng.metrics.snapshot();
        medians.push(secs);
        targets.push(host);
        t.add_with(
            label,
            secs,
            "s",
            vec![
                ("read_gb".into(), m.io_read_bytes as f64 / 1e9),
                ("write_gb".into(), m.io_write_bytes as f64 / 1e9),
                ("faults_injected".into(), m.faults_injected as f64),
                ("io_retries".into(), m.io_retries as f64),
                ("checksum_failures".into(), m.checksum_failures as f64),
            ],
        );
    }
    t.print();

    let ratio = medians[1] / medians[0];
    let within_bound = ratio <= 1.05;
    let bitexact = targets[1] == targets[0] && targets[2] == targets[0];
    println!(
        "\nchecksums+retry machinery: {:.1}% overhead fault-free — {}",
        (ratio - 1.0) * 100.0,
        if within_bound {
            "PASS: within the 5% acceptance bound"
        } else {
            "FAIL: protections cost more than 5% wall-time"
        }
    );
    println!(
        "targets {}",
        if bitexact {
            "PASS: bit-identical across all three configurations"
        } else {
            "FAIL: fault tolerance changed the result"
        }
    );

    let mut report = BenchReport::new("fault_overhead");
    report.add_table(&t);
    report.add_check("checksum-overhead<=5pct", within_bound);
    report.add_check("bit-identical-protected", bitexact);
    report.write(std::path::Path::new(&json_dir)).expect("bench json");

    let _ = std::fs::remove_dir_all(&dir);
    // fail loudly after the report is written: CI records the numbers
    // either way, and the gate also checks the `checks` array
    assert!(
        within_bound && bitexact,
        "fault-overhead acceptance failed (ratio {ratio:.3}, bitexact {bitexact})"
    );
}
