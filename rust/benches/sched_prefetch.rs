//! Bench: locality-aware scheduling + multi-worker read-ahead (§III-B3/F).
//!
//! PR 1 restricted async read-ahead to single-worker passes (the atomic
//! counter dispatch made partition ownership non-deterministic, so a
//! prefetch would race the worker owning the next partition and
//! double-read). With the range scheduler each worker owns a contiguous
//! range and prefetches the next partition *of its own range*; the
//! cache's single-flight registry coalesces residual races. This bench
//! shows the payoff: a multi-worker EM pass whose compute is comparable
//! to its (throttled) I/O no longer alternates read/compute — with
//! read-ahead off each pass pays `io + compute`, with it on roughly
//! `max(io, compute)`.
//!
//! Layout: a 32 MiB EM matrix against an 8 MiB cache (every pass is
//! cold) and a simulated-SSD bandwidth throttle; each pass computes the
//! Gramian (`crossprod`), the §IV inner-product hot loop. Steal /
//! prefetch / coalesced-read counters come from `metrics.rs`.
//!
//! Run: `cargo bench --bench sched_prefetch -- [--iters N] [--json-dir DIR]`
//! (`--iters` overrides the pass count, default 3). Emits
//! `BENCH_sched_prefetch.json` for the CI gate.

use std::sync::Arc;
use std::time::Instant;

use flashmatrix::config::{EngineConfig, StorageKind, ThrottleConfig};
use flashmatrix::datasets;
use flashmatrix::fmr::Engine;
use flashmatrix::harness::BenchReport;
use flashmatrix::util::bench::{bench_args, Table};

/// Simulated SSD bandwidth: 32 MiB of reads per pass ≈ 0.25 s, the same
/// order as the Gramian compute, so I/O/compute overlap is visible.
const SSD_BPS: u64 = 128 << 20;
/// Smaller than the matrix: every pass streams cold (§III-B3 worst case).
const CACHE_BYTES: usize = 8 << 20;
const ROWS: u64 = 1 << 19; // x 8 cols x 8 B = 32 MiB
const COLS: u64 = 8;
const THREADS: usize = 2;

fn engine(label: &str, dir: &std::path::Path, prefetch_depth: usize) -> Arc<Engine> {
    Engine::new(EngineConfig {
        storage: StorageKind::External,
        data_dir: dir.join(label.replace(' ', "-")),
        em_cache_bytes: CACHE_BYTES,
        prefetch_depth,
        throttle: Some(ThrottleConfig {
            read_bytes_per_sec: SSD_BPS,
            write_bytes_per_sec: SSD_BPS,
        }),
        threads: THREADS,
        numa_nodes: 2,
        xla_dispatch: false,
        ..EngineConfig::default()
    })
    .expect("engine")
}

/// `iters` Gramian passes over a cold-streaming EM matrix; returns timed
/// seconds (generation and its throttled writes are excluded).
fn run(eng: &Arc<Engine>, iters: usize) -> f64 {
    let x = datasets::uniform(eng, ROWS, COLS, -1.0, 1.0, 7, None).expect("dataset");
    // drain the buckets' standing burst: the timed passes pay the full
    // configured rate, so the overlap comparison is deterministic
    eng.ssd.drain_bursts();
    let t0 = Instant::now();
    let mut acc = 0.0;
    for _ in 0..iters {
        let g = x.crossprod(&x).expect("crossprod pass");
        acc += g.get(0, 0).as_f64();
    }
    std::hint::black_box(acc);
    t0.elapsed().as_secs_f64()
}

fn main() {
    let args = bench_args();
    let iters = args.usize_or("iters", 3);
    let json_dir = args.get_or("json-dir", ".").to_string();
    let dir = std::env::temp_dir().join(format!("fm-sched-prefetch-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench data dir");

    let mut t = Table::new(format!(
        "§III-B3/F multi-worker read-ahead: {iters} Gramian passes over \
         {} MiB EM ({} workers, cache {} MiB, SSD {} MiB/s)",
        (ROWS * COLS * 8) >> 20,
        THREADS,
        CACHE_BYTES >> 20,
        SSD_BPS >> 20
    ));
    let mut secs_by_cfg = Vec::new();
    for (label, depth) in [("read-ahead off", 0usize), ("read-ahead on", 4usize)] {
        let eng = engine(label, &dir, depth);
        eng.metrics.reset();
        let secs = run(&eng, iters);
        let m = eng.metrics.snapshot();
        secs_by_cfg.push(secs);
        t.add_with(
            label,
            secs,
            "s",
            vec![
                ("prefetches".into(), m.prefetch_issued as f64),
                ("coalesced".into(), m.singleflight_coalesced as f64),
                ("steals".into(), m.sched_steals as f64),
                ("remote_steals".into(), m.sched_steals_remote as f64),
                ("read_gb".into(), m.io_read_bytes as f64 / 1e9),
            ],
        );
    }
    t.print();

    let (off_secs, on_secs) = (secs_by_cfg[0], secs_by_cfg[1]);
    let overlap_wins = on_secs < off_secs;
    println!(
        "\nread-ahead on vs off: {:.2}x — {}",
        off_secs / on_secs,
        if overlap_wins {
            "PASS: multi-worker passes overlap I/O with compute"
        } else {
            "FAIL: read-ahead did not help the multi-worker pass"
        }
    );

    let mut report = BenchReport::new("sched_prefetch");
    report.add_table(&t);
    report.add_check("readahead-beats-off", overlap_wins);
    report.write(std::path::Path::new(&json_dir)).expect("bench json");

    let _ = std::fs::remove_dir_all(&dir);
}
