//! Bench: Fig 7 — single-thread FlashMatrix (IM and EM) vs the R-style
//! C/FORTRAN reference implementations, plus Fig 8's thread sweep.
//!
//! `cargo bench --bench fig7_single_thread -- [--n N] [--max-threads T]
//! [--json-dir DIR]` (`--n` overrides the Fig 7 row count). The harness
//! drains leftover simulated-SSD bursts before each timed region. Emits
//! `BENCH_fig7_single_thread.json`.

use flashmatrix::harness::{self, BenchReport, Scale};
use flashmatrix::util::bench::bench_args;

fn main() {
    let args = bench_args();
    let mut s = Scale::default();
    s.n_small = args.u64_or("n", s.n_small);
    let default_max = std::thread::available_parallelism()
        .map(|n| n.get() * 2)
        .unwrap_or(4);
    let max_t = args.usize_or("max-threads", default_max);
    let json_dir = args.get_or("json-dir", ".").to_string();

    let mut report = BenchReport::new("fig7_single_thread");
    let t = harness::fig7(&s).expect("fig7");
    t.print();
    report.add_table(&t);
    let t = harness::fig8(&s, max_t).expect("fig8");
    t.print();
    report.add_table(&t);
    report.write(std::path::Path::new(&json_dir)).expect("bench json");
}
