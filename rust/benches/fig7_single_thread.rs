//! Bench: Fig 7 — single-thread FlashMatrix (IM and EM) vs the R-style
//! C/FORTRAN reference implementations, plus Fig 8's thread sweep.
//!
//! `cargo bench --bench fig7_single_thread`

use flashmatrix::harness::{self, Scale};

fn main() {
    let mut s = Scale::default();
    if let Ok(n) = std::env::var("FM_BENCH_N") {
        s.n_small = n.parse().unwrap_or(s.n_small);
    }
    let t = harness::fig7(&s).expect("fig7");
    t.print();
    let max_t = std::thread::available_parallelism()
        .map(|n| n.get() * 2)
        .unwrap_or(4);
    let t = harness::fig8(&s, max_t).expect("fig8");
    t.print();
}
