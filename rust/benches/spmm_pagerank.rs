//! Bench: streaming-SpMM PageRank ablation — in-memory vs external-memory
//! with the partition cache deliberately smaller than the edge matrix, so
//! every power iteration re-streams the edges through cache replacement
//! (the out-of-core scenario the sparse subsystem exists for).
//!
//! Three configurations over the same synthetic graph:
//! * `FM-IM`            — edges in memory (baseline);
//! * `FM-EM cache<edges`— edges on the simulated SSD, `em_cache_bytes`
//!                        capped at ~1/4 of the edge-matrix bytes;
//! * `FM-EM cache-off`  — same, `em_cache_bytes = 0` (every partition
//!                        read pays the throttled store).
//!
//! All runs are single-threaded so ranks must be **bit-identical** across
//! configurations (the acceptance check printed at the end); per-config
//! sub-values expose `spmm_nnz`, I/O bytes and cache evictions.
//!
//! Run: `cargo bench --bench spmm_pagerank -- [--nodes N] [--json-dir DIR]`
//! (`--nodes` overrides the node count, default 65536 — the flag CI uses
//! for its smoke run). Emits `BENCH_spmm_pagerank.json` for the CI gate.

use std::sync::Arc;
use std::time::Instant;

use flashmatrix::algs;
use flashmatrix::config::{EngineConfig, StorageKind, ThrottleConfig};
use flashmatrix::datasets;
use flashmatrix::fmr::Engine;
use flashmatrix::harness::BenchReport;
use flashmatrix::util::bench::{bench_args, Table};

const SSD_BPS: u64 = 512 << 20;
const MAX_DEG: u64 = 16;
const DAMPING: f64 = 0.85;
const ITERS: usize = 8;

fn engine(dir: &std::path::Path, external: bool, cache_bytes: usize) -> Arc<Engine> {
    Engine::new(EngineConfig {
        storage: if external {
            StorageKind::External
        } else {
            StorageKind::InMem
        },
        data_dir: dir.to_path_buf(),
        em_cache_bytes: cache_bytes,
        prefetch_depth: if cache_bytes > 0 { 2 } else { 0 },
        throttle: external.then_some(ThrottleConfig {
            read_bytes_per_sec: SSD_BPS,
            write_bytes_per_sec: SSD_BPS,
        }),
        threads: 1, // bit-exact ranks across configurations
        xla_dispatch: false,
        ..EngineConfig::default()
    })
    .expect("engine")
}

fn main() {
    let args = bench_args();
    let n = args.u64_or("nodes", 1 << 16);
    let json_dir = args.get_or("json-dir", ".").to_string();
    let dir = std::env::temp_dir().join(format!("fm-spmm-pagerank-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench data dir");

    // size the constrained cache off the real edge footprint (probe run)
    let probe = engine(&dir, false, 0);
    let (g0, _) = datasets::pagerank_graph(&probe, n, MAX_DEG, 42, None).expect("probe graph");
    let edge_bytes = g0.sparse_bytes().expect("sparse") as usize;
    drop(g0);
    let small_cache = (edge_bytes / 4).max(1 << 16);

    let mut t = Table::new(format!(
        "SpMM PageRank ablation: {n} nodes, max_deg {MAX_DEG}, {ITERS} iters, \
         edges {:.1} MiB, constrained cache {:.1} MiB, SSD {} MiB/s",
        edge_bytes as f64 / (1 << 20) as f64,
        small_cache as f64 / (1 << 20) as f64,
        SSD_BPS >> 20
    ));

    let mut ranks: Vec<(&str, Vec<f64>)> = Vec::new();
    for (label, external, cache) in [
        ("FM-IM", false, 0usize),
        ("FM-EM cache<edges", true, small_cache),
        ("FM-EM cache-off", true, 0usize),
    ] {
        let eng = engine(&dir, external, cache);
        let (g, dangling) =
            datasets::pagerank_graph(&eng, n, MAX_DEG, 42, None).expect("graph");
        if external {
            // cold start: drop the write-through copies so iterations pay
            // the cache-replacement traffic the ablation measures
            if let Some(c) = &eng.cache {
                c.clear();
            }
        }
        eng.metrics.reset();
        let t0 = Instant::now();
        let pr = algs::pagerank(&g, &dangling, DAMPING, ITERS, 0.0).expect("pagerank");
        let secs = t0.elapsed().as_secs_f64();
        let m = eng.metrics.snapshot();
        t.add_with(
            label,
            secs,
            "s",
            vec![
                ("spmm_nnz".into(), m.spmm_nnz as f64),
                ("read_gb".into(), m.io_read_bytes as f64 / 1e9),
                ("cache_hits".into(), m.cache_hits as f64),
                ("cache_evictions".into(), m.cache_evictions as f64),
                ("rank_sum".into(), pr.ranks.iter().sum()),
            ],
        );
        ranks.push((label, pr.ranks));
    }
    t.print();

    let (_, im_ranks) = &ranks[0];
    let mut ok = true;
    let mut report = BenchReport::new("spmm_pagerank");
    report.add_table(&t);
    for (label, r) in &ranks[1..] {
        let identical = r.len() == im_ranks.len()
            && r
                .iter()
                .zip(im_ranks)
                .all(|(a, b)| a.to_bits() == b.to_bits());
        println!(
            "{label} vs FM-IM: {}",
            if identical {
                "PASS: ranks bit-identical"
            } else {
                ok = false;
                "FAIL: ranks diverged"
            }
        );
        report.add_check(format!("bit-identical: {label}"), identical);
    }
    report.write(std::path::Path::new(&json_dir)).expect("bench json");
    assert!(ok, "out-of-core PageRank must be bit-identical to in-memory");

    let _ = std::fs::remove_dir_all(&dir);
}
