//! Bench: parallel delimited-text ingestion (`fm.load.dense.matrix`) —
//! 1 parse worker vs N, in-memory vs external-memory targets.
//!
//! The ingestion pipeline is two-phase: a chunk scan (row counts, text
//! CRCs, factor vocabularies) followed by partition-aligned parse+write.
//! Both phases fan chunks out to `ingest_workers`; reads of the source
//! text go through the simulated SSD, so a deterministic bandwidth
//! throttle makes the I/O half of the pipeline a fixed cost. With one
//! worker the pass pays `read + parse` serially; with N workers the
//! parses run concurrently underneath the throttled reads, so the pass
//! costs roughly `max(read, parse/N)` — the overlap-plus-parallelism win
//! this bench pins, on the same corpus for an in-memory and an
//! out-of-core target.
//!
//! Worker count and chunk geometry are forbidden from leaking into the
//! bytes (each partition is parsed from an exclusive newline-aligned
//! range by exactly one worker), so acceptance is (asserted, and
//! recorded in `BENCH_ingest.json` for the CI regression gate):
//! * N workers strictly faster than 1 on both storage targets, and
//! * all four loaded matrices **bit-identical**.
//!
//! Run: `cargo bench --bench ingest -- [--iters N] [--json-dir DIR]`

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use flashmatrix::config::{EngineConfig, StorageKind, ThrottleConfig};
use flashmatrix::fmr::Engine;
use flashmatrix::harness::BenchReport;
use flashmatrix::matrix::HostMat;
use flashmatrix::util::bench::{bench_args, Table};
use flashmatrix::{EngineExt, LoadOptions, Schema};

/// Source text streams at this rate through the simulated SSD; both
/// ingest phases read every byte once, so the I/O floor is fixed.
const SSD_BPS: u64 = 256 << 20;
const FILES: usize = 4;
const ROWS_PER_FILE: u64 = 150_000;
const WORKERS: usize = 4;

/// Deterministic `FFFI` corpus (three float features + a small-range
/// integer category), counter-based on the global row id, with NA cells
/// on one modulus and whitespace padding on another — the same recipe as
/// `tests/ingest.rs`, sized for timing instead of assertions.
fn write_corpus(dir: &Path) -> Vec<PathBuf> {
    use std::fmt::Write as _;
    let mut paths = Vec::new();
    for f in 0..FILES {
        let mut text = String::new();
        for r in 0..ROWS_PER_FILE {
            let g = f as u64 * ROWS_PER_FILE + r;
            let a = (g.wrapping_mul(2654435761) % 1000) as f64 / 500.0 - 1.0;
            let b = (g.wrapping_mul(40503) % 777) as f64 / 388.5 - 1.0;
            let c = (g.wrapping_mul(9176) % 333) as f64 / 166.5 - 1.0;
            let cat = g % 5;
            if g % 97 == 13 {
                writeln!(text, "{a},NA,{c},{cat}").unwrap();
            } else if g % 101 == 7 {
                writeln!(text, " {a} , {b} ,{c},{cat}").unwrap();
            } else {
                writeln!(text, "{a},{b},{c},{cat}").unwrap();
            }
        }
        let p = dir.join(format!("part-{f}.csv"));
        std::fs::write(&p, text).expect("corpus file");
        paths.push(p);
    }
    paths
}

fn engine(label: &str, dir: &Path, storage: StorageKind, workers: usize) -> Arc<Engine> {
    Engine::new(EngineConfig {
        storage,
        data_dir: dir.join(label.replace(' ', "-")),
        ingest_workers: workers,
        ingest_chunk_bytes: 1 << 20, // many chunks per file
        em_cache_bytes: 8 << 20,     // EM target streams through a small cache
        throttle: Some(ThrottleConfig {
            read_bytes_per_sec: SSD_BPS,
            write_bytes_per_sec: SSD_BPS,
        }),
        threads: 1, // bit-exact sinks; parse parallelism is the knob under test
        xla_dispatch: false,
        ..EngineConfig::default()
    })
    .expect("engine")
}

/// `iters` full loads of the corpus; returns (timed seconds, last load
/// as a host matrix for the bit-exactness check — read back untimed).
fn run(eng: &Arc<Engine>, paths: &[PathBuf], iters: usize) -> (f64, HostMat) {
    let o = LoadOptions::new(Schema::parse("FFFI").expect("schema"));
    // drain the token buckets' standing burst so every timed byte pays
    // the configured rate — the overlap win is deterministic, not noise
    eng.ssd.drain_bursts();
    let mut last = None;
    let t0 = Instant::now();
    for _ in 0..iters {
        last = Some(eng.load_dense_matrix(paths, &o).expect("load"));
    }
    let secs = t0.elapsed().as_secs_f64();
    let host = last.expect("at least one iter").to_host().expect("readback");
    (secs, host)
}

fn main() {
    let args = bench_args();
    let iters = args.usize_or("iters", 2);
    let json_dir = args.get_or("json-dir", ".").to_string();
    let dir = std::env::temp_dir().join(format!("fm-ingest-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench data dir");
    let corpus_dir = dir.join("corpus");
    std::fs::create_dir_all(&corpus_dir).expect("corpus dir");
    let paths = write_corpus(&corpus_dir);
    let text_mb = paths
        .iter()
        .map(|p| std::fs::metadata(p).expect("corpus meta").len())
        .sum::<u64>()
        >> 20;

    let mut t = Table::new(format!(
        "delimited ingestion: {iters} loads of {FILES}-file / {}-row / \
         ~{text_mb} MiB FFFI corpus (SSD {} MiB/s each way)",
        FILES as u64 * ROWS_PER_FILE,
        SSD_BPS >> 20
    ));

    let configs = [
        ("im 1-worker".to_string(), StorageKind::InMem, 1),
        (format!("im {WORKERS}-workers"), StorageKind::InMem, WORKERS),
        ("em 1-worker".to_string(), StorageKind::External, 1),
        (
            format!("em {WORKERS}-workers"),
            StorageKind::External,
            WORKERS,
        ),
    ];
    let mut secs_by_cfg = Vec::new();
    let mut targets: Vec<HostMat> = Vec::new();
    for (label, storage, workers) in configs.iter() {
        let label = label.as_str();
        let eng = engine(label, &dir, storage.clone(), *workers);
        eng.metrics.reset();
        let (secs, host) = run(&eng, &paths, iters);
        let m = eng.metrics.snapshot();
        assert_eq!(
            m.ingest_rows,
            iters as u64 * FILES as u64 * ROWS_PER_FILE,
            "{label}: the loader must see every corpus row"
        );
        secs_by_cfg.push(secs);
        targets.push(host);
        t.add_with(
            label,
            secs,
            "s",
            vec![
                ("ingest_chunks".into(), m.ingest_chunks as f64),
                ("ingest_rows".into(), m.ingest_rows as f64),
                ("ingest_na_cells".into(), m.ingest_na_cells as f64),
                ("read_gb".into(), m.io_read_bytes as f64 / 1e9),
                ("write_gb".into(), m.io_write_bytes as f64 / 1e9),
            ],
        );
    }
    t.print();

    let im_faster = secs_by_cfg[1] < secs_by_cfg[0];
    let em_faster = secs_by_cfg[3] < secs_by_cfg[2];
    let bitexact = targets.iter().all(|h| *h == targets[0]);
    println!(
        "\nim {WORKERS}w vs 1w: {:.2}x — em {WORKERS}w vs 1w: {:.2}x — {}",
        secs_by_cfg[0] / secs_by_cfg[1],
        secs_by_cfg[2] / secs_by_cfg[3],
        if im_faster && em_faster {
            "PASS: parses overlap throttled reads and each other"
        } else {
            "FAIL: parallel ingestion did not beat one worker"
        }
    );
    println!(
        "targets {}",
        if bitexact {
            "PASS: bit-identical across workers and storage"
        } else {
            "FAIL: worker count or storage leaked into the bytes"
        }
    );

    let mut report = BenchReport::new("ingest");
    report.add_table(&t);
    report.add_check("parallel-strictly-faster-im", im_faster);
    report.add_check("parallel-strictly-faster-em", em_faster);
    report.add_check("bit-identical-parallel", bitexact);
    report.write(std::path::Path::new(&json_dir)).expect("bench json");

    let _ = std::fs::remove_dir_all(&dir);
    // fail loudly after the report is written: CI records the numbers
    // either way, and the gate also checks the `checks` array
    assert!(
        im_faster && em_faster && bitexact,
        "ingest acceptance failed (im {im_faster}, em {em_faster}, bitexact {bitexact})"
    );
}
