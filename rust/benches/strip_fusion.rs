//! Bench: liveness-driven register reuse + in-place/fused strip kernels.
//!
//! The strip evaluator used to allocate a fresh heap `Buf` for every
//! instruction of every CPU strip. With the compile-time register plan
//! (`exec/pipeline.rs`) the hot Sapply -> MapplyScalar -> RowAgg chain
//! instead (a) peephole-fuses the elementwise steps into one traversal,
//! (b) runs them in place on the dead load register, and (c) recycles
//! every dead register through the worker's `StripPool` — so steady-state
//! strips allocate nothing at all.
//!
//! This bench ablates each feature (`recycle_chunks`, `inplace_ops`,
//! `peephole_fuse`) on a fused Sapply -> MapplyScalar -> MapplyScalar ->
//! RowAgg pipeline and reports strips/sec plus the `buf_allocs` /
//! `buf_reuses` / `inplace_ops` / `fused_chain_len` counters. It fails
//! loudly if the optimized configuration allocates as much as the
//! unoptimized one, or if any configuration's results are not
//! bit-identical to the all-off baseline.
//!
//! Run: `cargo bench --bench strip_fusion -- [--iters N] [--json-dir DIR]`
//! (`--iters` overrides the pass count, default 3). Emits
//! `BENCH_strip_fusion.json` for the CI gate.

use std::sync::Arc;
use std::time::Instant;

use flashmatrix::config::EngineConfig;
use flashmatrix::datasets;
use flashmatrix::dtype::Scalar;
use flashmatrix::fmr::{Engine, FmMatrix};
use flashmatrix::harness::BenchReport;
use flashmatrix::matrix::{HostMat, Partitioning};
use flashmatrix::util::bench::{bench_args, Table};
use flashmatrix::vudf::BinOp;

const ROWS: u64 = 1 << 19; // x 8 cols x 8 B = 32 MiB in-mem
const COLS: u64 = 8;

fn engine(recycle: bool, inplace: bool, peephole: bool) -> Arc<Engine> {
    Engine::new(EngineConfig {
        recycle_chunks: recycle,
        inplace_ops: inplace,
        peephole_fuse: peephole,
        xla_dispatch: false,
        ..EngineConfig::default()
    })
    .expect("engine")
}

/// The chain under test: sq -> *0.5 -> +1 -> rowSums, one fused pass.
fn pipeline(x: &FmMatrix) -> HostMat {
    x.sq()
        .and_then(|m| m.mapply_scalar(Scalar::F64(0.5), BinOp::Mul, true))
        .and_then(|m| m.mapply_scalar(Scalar::F64(1.0), BinOp::Add, true))
        .and_then(|m| m.row_sums())
        .and_then(|m| m.to_host())
        .expect("pipeline pass")
}

/// Exact CPU-strip count of one pass over the ROWS x COLS matrix.
fn strips_per_pass(cpu_part_bytes: usize) -> usize {
    let parts = Partitioning::new(ROWS, COLS);
    (0..parts.n_parts())
        .map(|i| parts.cpu_ranges(i, cpu_part_bytes).len())
        .sum()
}

fn main() {
    let args = bench_args();
    let iters = args.usize_or("iters", 3);
    let json_dir = args.get_or("json-dir", ".").to_string();

    let mut t = Table::new(format!(
        "strip-fusion ablation: {iters} Sapply->MapplyScalar->RowAgg passes \
         over {} MiB in-mem ({} strips/pass)",
        (ROWS * COLS * 8) >> 20,
        strips_per_pass(EngineConfig::default().cpu_part_bytes),
    ));

    // (label, recycle_chunks, inplace_ops, peephole_fuse)
    let configs = [
        ("all-on", true, true, true),
        ("no-recycle", false, true, true),
        ("no-inplace", true, false, true),
        ("no-peephole", true, true, false),
        ("all-off", false, false, false),
    ];

    let mut baseline: Option<HostMat> = None;
    let mut allocs_on = u64::MAX;
    let mut allocs_off = 0u64;
    let mut bitexact = true;
    for (label, recycle, inplace, peephole) in configs {
        let eng = engine(recycle, inplace, peephole);
        let x = datasets::uniform(&eng, ROWS, COLS, -1.0, 1.0, 11, None).expect("dataset");
        let mut last = pipeline(&x); // warm up + correctness sample
        eng.metrics.reset();
        let t0 = Instant::now();
        for _ in 0..iters {
            last = pipeline(&x);
        }
        let secs = t0.elapsed().as_secs_f64();
        let m = eng.metrics.snapshot();

        // bit-exact parity across every configuration (the "all-off"
        // fresh-alloc path is the reference)
        match &baseline {
            None => baseline = Some(last.clone()),
            Some(b) => {
                if *b != last {
                    bitexact = false;
                }
            }
        }
        if label == "all-on" {
            allocs_on = m.buf_allocs;
        }
        if label == "all-off" {
            allocs_off = m.buf_allocs;
        }

        let strips = (strips_per_pass(eng.config.cpu_part_bytes) * iters) as f64;
        t.add_with(
            label,
            strips / secs,
            "strips/s",
            vec![
                ("secs".into(), secs),
                ("buf_allocs".into(), m.buf_allocs as f64),
                ("buf_reuses".into(), m.buf_reuses as f64),
                ("inplace_ops".into(), m.inplace_ops as f64),
                ("fused_len".into(), m.fused_chain_len as f64),
            ],
        );
    }
    t.print();

    let fewer = allocs_on < allocs_off;
    println!(
        "\nbuf_allocs all-on vs all-off: {allocs_on} vs {allocs_off} — {}",
        if fewer && bitexact {
            "PASS: recycling+in-place allocate strictly less, bit-identical results"
        } else if !fewer {
            "FAIL: optimized config did not reduce strip allocations"
        } else {
            "FAIL: configurations disagree on results"
        }
    );
    let mut report = BenchReport::new("strip_fusion");
    report.add_table(&t);
    report.add_check("fewer-allocs-when-optimized", fewer);
    report.add_check("bit-identical-across-configs", bitexact);
    report.write(std::path::Path::new(&json_dir)).expect("bench json");

    // fail loudly: automation running this bench must see the regression
    assert!(
        fewer && bitexact,
        "strip-fusion acceptance check failed (fewer-allocs {fewer}, bitexact {bitexact})"
    );
}
