//! Bench: explicit lane kernels + register-blocked GEMM microkernels.
//!
//! `EngineConfig::simd_kernels` routes the strip evaluator's hot paths
//! through hand-unrolled lane kernels (4-wide f64 accumulator arrays the
//! autovectorizer keeps in registers) and register-blocked microkernels
//! (an MR=8 row panel behind `inner_prod_small`, a KB=4 dot-product block
//! behind the `crossprod` wide-tall sink). This bench ablates the knob on
//! two workloads and fails loudly if the kernels stop paying for
//! themselves:
//!
//! * a peephole-fused 7-step elementwise chain (sq -> five scalar steps
//!   -> rowSums), where the SIMD path must reach >= 1.5x strips/sec over
//!   scalar single-threaded in memory, and
//! * a 32-column `crossprod` (the inner-wide-tall GEMM sink), where the
//!   blocked kernel must reach >= 2x.
//!
//! Both workloads also run externally (FM-EM, throttled simulated SSD,
//! cold cache) so the JSON records how much of the win survives under
//! I/O, and every configuration's results must stay bit-identical to the
//! scalar path — the lane kernels are reorderings of independent outputs,
//! never of any one output's accumulation.
//!
//! Run: `cargo bench --bench simd_kernels -- [--iters N] [--json-dir DIR]`
//! (`--iters` overrides the pass count, default 3). Emits
//! `BENCH_simd_kernels.json` for the CI gate.

use std::sync::Arc;
use std::time::Instant;

use flashmatrix::datasets;
use flashmatrix::dtype::Scalar;
use flashmatrix::fmr::{Engine, FmMatrix};
use flashmatrix::harness::{config_for, BenchReport, Mode, Scale};
use flashmatrix::matrix::{HostMat, Partitioning};
use flashmatrix::util::bench::{bench_args, Table};
use flashmatrix::vudf::BinOp;

const FUSE_ROWS: u64 = 1 << 19; // x 8 cols x 8 B = 32 MiB in-mem
const FUSE_COLS: u64 = 8;
const GEMM_ROWS: u64 = 1 << 17; // x 32 cols x 8 B = 32 MiB in-mem
const GEMM_COLS: u64 = 32;

fn engine(mode: Mode, simd: bool) -> Arc<Engine> {
    let s = Scale::default();
    let mut cfg = config_for(&s, mode, 1);
    cfg.simd_kernels = simd;
    cfg.xla_dispatch = false; // isolate the engine's own kernels
    Engine::new(cfg).expect("engine")
}

/// The elementwise chain under test: sq plus five scalar steps, all
/// peephole-fused into one `FusedChain` traversal, then rowSums.
fn fused_pass(x: &FmMatrix) -> HostMat {
    x.sq()
        .and_then(|m| m.mapply_scalar(Scalar::F64(0.5), BinOp::Mul, true))
        .and_then(|m| m.mapply_scalar(Scalar::F64(1.0), BinOp::Add, true))
        .and_then(|m| m.mapply_scalar(Scalar::F64(2.0), BinOp::Mul, true))
        .and_then(|m| m.mapply_scalar(Scalar::F64(3.0), BinOp::Sub, true))
        .and_then(|m| m.mapply_scalar(Scalar::F64(0.25), BinOp::Mul, true))
        .and_then(|m| m.row_sums())
        .and_then(|m| m.to_host())
        .expect("fused pass")
}

/// Exact CPU-strip count of one pass over a `rows x cols` matrix.
fn strips_per_pass(rows: u64, cols: u64, cpu_part_bytes: usize) -> usize {
    let parts = Partitioning::new(rows, cols);
    (0..parts.n_parts())
        .map(|i| parts.cpu_ranges(i, cpu_part_bytes).len())
        .sum()
}

fn bytes(m: &HostMat) -> Vec<u8> {
    // NaN-safe bit comparison (HostMat's PartialEq is IEEE, not bitwise).
    m.buf.to_bytes()
}

fn main() {
    let args = bench_args();
    let iters = args.usize_or("iters", 3);
    let json_dir = args.get_or("json-dir", ".").to_string();

    let mut t = Table::new(format!(
        "simd-kernels ablation: {iters}-pass fused chain ({} MiB) and \
         crossprod GEMM ({} MiB), single thread",
        (FUSE_ROWS * FUSE_COLS * 8) >> 20,
        (GEMM_ROWS * GEMM_COLS * 8) >> 20,
    ));

    let mut fused_secs = [0.0f64; 2]; // [scalar, simd] IM
    let mut gemm_secs = [0.0f64; 2];
    let mut fused_ref: Option<Vec<u8>> = None;
    let mut gemm_ref: Option<Vec<u8>> = None;
    let mut bitexact = true;
    let mut counters_active = true;

    for mode in [Mode::FmIm, Mode::FmEm] {
        for simd in [false, true] {
            let label = if simd { "simd" } else { "scalar" };

            // -- fused elementwise chain --------------------------------
            let eng = engine(mode, simd);
            let x = datasets::uniform(&eng, FUSE_ROWS, FUSE_COLS, -1.0, 1.0, 11, None)
                .expect("dataset");
            let mut last = fused_pass(&x); // warm up + correctness sample
            eng.ssd.drain_bursts();
            eng.metrics.reset();
            let t0 = Instant::now();
            for _ in 0..iters {
                last = fused_pass(&x);
            }
            let secs = t0.elapsed().as_secs_f64();
            let m = eng.metrics.snapshot();
            match &fused_ref {
                None => fused_ref = Some(bytes(&last)),
                Some(b) => bitexact &= *b == bytes(&last),
            }
            if simd {
                counters_active &= m.simd_strips > 0 && m.simd_lanes_f64 > 0;
            }
            if mode == Mode::FmIm {
                fused_secs[simd as usize] = secs;
            }
            let strips =
                (strips_per_pass(FUSE_ROWS, FUSE_COLS, eng.config.cpu_part_bytes) * iters) as f64;
            t.add_with(
                format!("fused-chain {} {}", mode.label(), label),
                strips / secs,
                "strips/s",
                vec![
                    ("secs".into(), secs),
                    ("simd_strips".into(), m.simd_strips as f64),
                    ("simd_lanes".into(), m.simd_lanes_f64 as f64),
                ],
            );

            // -- crossprod (inner-wide-tall GEMM sink) ------------------
            let eng = engine(mode, simd);
            let x = datasets::uniform(&eng, GEMM_ROWS, GEMM_COLS, -1.0, 1.0, 13, None)
                .expect("dataset");
            let mut ct = x.crossprod(&x).expect("crossprod"); // warm up
            eng.ssd.drain_bursts();
            eng.metrics.reset();
            let t0 = Instant::now();
            for _ in 0..iters {
                ct = x.crossprod(&x).expect("crossprod");
            }
            let secs = t0.elapsed().as_secs_f64();
            let m = eng.metrics.snapshot();
            match &gemm_ref {
                None => gemm_ref = Some(bytes(&ct)),
                Some(b) => bitexact &= *b == bytes(&ct),
            }
            if simd {
                counters_active &= m.gemm_panels > 0;
            }
            if mode == Mode::FmIm {
                gemm_secs[simd as usize] = secs;
            }
            t.add_with(
                format!("crossprod {} {}", mode.label(), label),
                iters as f64 / secs,
                "passes/s",
                vec![
                    ("secs".into(), secs),
                    ("gemm_panels".into(), m.gemm_panels as f64),
                ],
            );
        }
    }
    t.print();

    let fused_speedup = fused_secs[0] / fused_secs[1];
    let gemm_speedup = gemm_secs[0] / gemm_secs[1];
    let fused_ok = fused_speedup >= 1.5;
    let gemm_ok = gemm_speedup >= 2.0;
    println!(
        "\nfused-chain IM speedup {fused_speedup:.2}x (need >= 1.5), \
         crossprod IM speedup {gemm_speedup:.2}x (need >= 2.0), \
         bit-identical {bitexact}, counters {counters_active}"
    );

    let mut report = BenchReport::new("simd_kernels");
    report.add_table(&t);
    report.add_check("simd-fused-speedup>=1.5x", fused_ok);
    report.add_check("gemm-speedup>=2x", gemm_ok);
    report.add_check("bit-identical-default", bitexact);
    report.add_check("simd-counters-active", counters_active);
    report.write(std::path::Path::new(&json_dir)).expect("bench json");

    // fail loudly: automation running this bench must see the regression
    assert!(
        fused_ok && gemm_ok && bitexact && counters_active,
        "simd-kernels acceptance failed (fused {fused_speedup:.2}x, gemm \
         {gemm_speedup:.2}x, bitexact {bitexact}, counters {counters_active})"
    );
}
