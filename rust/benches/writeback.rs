//! Bench: asynchronous write-back vs synchronous write-through (§III-B3,
//! the write half of the paper's I/O/compute overlap).
//!
//! PR 1/2 overlapped only the *read* side of out-of-core passes (cache +
//! read-ahead); every target-partition write was still a synchronous
//! write-through that stalled the worker mid-pass. With write-back on,
//! workers hand finished target partitions to the cache's background
//! writer thread and immediately claim the next unit, so the throttled
//! `pwrite` runs while the next partition is being read and computed.
//! The simulated SSD charges reads and writes to **separate** token
//! buckets (full duplex, like an SSD array), so a read+write pass costs
//! roughly `read + write` with write-through but `max(read, write)` with
//! write-back — the deterministic win this bench pins.
//!
//! Layout: an EM map pass (`sq()` materialize) over a 32 MiB matrix with
//! an 8 MiB partition cache (every pass streams cold) and a symmetric
//! bandwidth throttle. Read-ahead is OFF to isolate the write lever:
//! with it on, the prefetch thread already hides reads behind the
//! synchronous writes, so both configurations pipeline and the ablation
//! would measure nothing (`benches/sched_prefetch.rs` ablates the read
//! half on its own). The timed region covers the materialize passes
//! including each pass's flush barrier, so write-back gets no credit for
//! work it merely deferred. Acceptance (asserted, and recorded in
//! `BENCH_writeback.json` for the CI regression gate):
//! * write-back strictly faster than write-through, and
//! * the two target matrices **bit-identical**.
//!
//! Run: `cargo bench --bench writeback -- [--iters N] [--json-dir DIR]`

use std::sync::Arc;
use std::time::Instant;

use flashmatrix::config::{EngineConfig, StorageKind, ThrottleConfig};
use flashmatrix::datasets;
use flashmatrix::fmr::Engine;
use flashmatrix::harness::BenchReport;
use flashmatrix::matrix::HostMat;
use flashmatrix::util::bench::{bench_args, Table};

/// Symmetric read/write budget: 32 MiB of reads ≈ 0.125 s per pass, the
/// same again for writes — overlap halves the pass.
const SSD_BPS: u64 = 256 << 20;
/// Far smaller than the matrix: every pass streams cold (§III-B3).
const CACHE_BYTES: usize = 8 << 20;
const ROWS: u64 = 1 << 19; // x 8 cols x 8 B = 32 MiB, 8 io partitions
const COLS: u64 = 8;

fn engine(label: &str, dir: &std::path::Path, writeback: bool) -> Arc<Engine> {
    Engine::new(EngineConfig {
        storage: StorageKind::External,
        data_dir: dir.join(label.replace(' ', "-")),
        em_cache_bytes: CACHE_BYTES,
        prefetch_depth: 0, // isolate the write half (see module docs)
        writeback,
        throttle: Some(ThrottleConfig {
            read_bytes_per_sec: SSD_BPS,
            write_bytes_per_sec: SSD_BPS,
        }),
        threads: 1, // bit-exact targets across configurations
        xla_dispatch: false,
        ..EngineConfig::default()
    })
    .expect("engine")
}

/// `iters` map-materialize passes (read 32 MiB + write 32 MiB each);
/// returns (timed seconds, final target as a host matrix for the
/// bit-exactness check — read back untimed).
fn run(eng: &Arc<Engine>, iters: usize) -> (f64, HostMat) {
    let x = datasets::uniform(eng, ROWS, COLS, -1.0, 1.0, 7, None).expect("dataset");
    if let Some(c) = &eng.cache {
        c.clear(); // drop generation's write-through copies: start cold
    }
    // drain the token buckets' standing burst so every timed byte pays
    // the configured rate — the overlap win is deterministic, not noise
    eng.ssd.drain_bursts();
    let mut last = None;
    let t0 = Instant::now();
    for _ in 0..iters {
        // one EM pass: stream x, write the sq() target (flush barrier
        // included — write-back must pay for what it deferred)
        last = Some(x.sq().and_then(|y| y.materialize()).expect("map pass"));
    }
    let secs = t0.elapsed().as_secs_f64();
    let host = last.expect("at least one iter").to_host().expect("readback");
    (secs, host)
}

fn main() {
    let args = bench_args();
    let iters = args.usize_or("iters", 3);
    let json_dir = args.get_or("json-dir", ".").to_string();
    let dir = std::env::temp_dir().join(format!("fm-writeback-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench data dir");

    let mut t = Table::new(format!(
        "§III-B3 write-back overlap: {iters} sq() materialize passes over \
         {} MiB EM (cache {} MiB, SSD {} MiB/s each way)",
        (ROWS * COLS * 8) >> 20,
        CACHE_BYTES >> 20,
        SSD_BPS >> 20
    ));

    let mut secs_by_cfg = Vec::new();
    let mut targets: Vec<HostMat> = Vec::new();
    for (label, writeback) in [("write-through", false), ("write-back", true)] {
        let eng = engine(label, &dir, writeback);
        eng.metrics.reset();
        let (secs, host) = run(&eng, iters);
        let m = eng.metrics.snapshot();
        if writeback {
            assert!(m.wb_enqueued > 0, "write-back config never queued a write");
        } else {
            assert_eq!(m.wb_enqueued, 0, "write-through config must not queue");
        }
        secs_by_cfg.push(secs);
        targets.push(host);
        t.add_with(
            label,
            secs,
            "s",
            vec![
                ("wb_enqueued".into(), m.wb_enqueued as f64),
                ("wb_coalesced".into(), m.wb_coalesced as f64),
                ("wb_flush_waits".into(), m.wb_flush_waits as f64),
                ("wb_discarded".into(), m.wb_discarded as f64),
                ("read_gb".into(), m.io_read_bytes as f64 / 1e9),
                ("write_gb".into(), m.io_write_bytes as f64 / 1e9),
                ("prefetches".into(), m.prefetch_issued as f64),
            ],
        );
    }
    t.print();

    let (wt_secs, wb_secs) = (secs_by_cfg[0], secs_by_cfg[1]);
    let faster = wb_secs < wt_secs;
    let bitexact = targets[0] == targets[1];
    println!(
        "\nwrite-back vs write-through: {:.2}x — {}",
        wt_secs / wb_secs,
        if faster {
            "PASS: writes overlap the next partition's read/compute"
        } else {
            "FAIL: write-back did not beat write-through"
        }
    );
    println!(
        "targets {}",
        if bitexact {
            "PASS: bit-identical"
        } else {
            "FAIL: write-back changed the result"
        }
    );

    let mut report = BenchReport::new("writeback");
    report.add_table(&t);
    report.add_check("writeback-strictly-faster", faster);
    report.add_check("bit-identical-target", bitexact);
    report.write(std::path::Path::new(&json_dir)).expect("bench json");

    let _ = std::fs::remove_dir_all(&dir);
    // fail loudly after the report is written: CI records the numbers
    // either way, and the gate also checks the `checks` array
    assert!(
        faster && bitexact,
        "write-back acceptance failed (faster {faster}, bitexact {bitexact})"
    );
}
