//! Microbenchmarks of the engine primitives: VUDF forms (vectorized vs
//! per-element), fused vs eager pipelines, sink kinds, and the XLA vs
//! native per-partition steps. These feed EXPERIMENTS.md §Perf.
//!
//! `cargo bench --bench genops_micro -- [--n N] [--json-dir DIR]`
//! (`--n` overrides the row count). Simulated-SSD bursts left over from
//! dataset creation are drained before every timed region. Emits
//! `BENCH_genops_micro.json`.

use flashmatrix::config::EngineConfig;
use flashmatrix::datasets;
use flashmatrix::fmr::Engine;
use flashmatrix::harness::BenchReport;
use flashmatrix::util::bench::{bench_args, measure, Table};
use flashmatrix::vudf::{AggOp, UnOp};

fn main() {
    let args = bench_args();
    let n = args.u64_or("n", 1_000_000);
    let json_dir = args.get_or("json-dir", ".").to_string();
    let mut t = Table::new(format!("genops microbenchmarks, {n}x8 f64"));

    for (label, vectorized) in [("vectorized", true), ("per-element", false)] {
        let eng = Engine::new(EngineConfig {
            vectorized_udf: vectorized,
            xla_dispatch: false,
            ..Default::default()
        })
        .unwrap();
        let x = datasets::uniform(&eng, n, 8, -1.0, 1.0, 3, None).unwrap();
        eng.ssd.drain_bursts();
        let s = measure(1, 5, || {
            x.sapply(UnOp::Abs).unwrap().agg(AggOp::Sum).unwrap()
        });
        let gbps = (n * 8 * 8) as f64 / s.secs() / 1e9;
        t.add_with(
            format!("sapply+agg {label}"),
            s.secs() * 1e3,
            "ms",
            vec![("GB/s".into(), gbps)],
        );
    }

    for (label, fuse) in [("fused", true), ("eager", false)] {
        let eng = Engine::new(EngineConfig {
            fuse_mem: fuse,
            fuse_cache: fuse,
            xla_dispatch: false,
            ..Default::default()
        })
        .unwrap();
        let x = datasets::uniform(&eng, n, 8, -1.0, 1.0, 3, None).unwrap();
        eng.ssd.drain_bursts();
        let s = measure(1, 5, || {
            // 4-op chain: |x| + x^2 -> sum
            x.abs()
                .unwrap()
                .add(&x.sq().unwrap())
                .unwrap()
                .sum()
                .unwrap()
        });
        t.add(format!("4-op chain {label}"), s.secs() * 1e3, "ms");
    }

    // sink kinds at fixed input
    let eng = Engine::new(EngineConfig {
        xla_dispatch: false,
        ..Default::default()
    })
    .unwrap();
    let x = datasets::uniform(&eng, n, 8, -1.0, 1.0, 3, None).unwrap();
    eng.ssd.drain_bursts();
    let s = measure(1, 5, || x.sum().unwrap());
    t.add("agg full", s.secs() * 1e3, "ms");
    let s = measure(1, 5, || x.col_sums().unwrap());
    t.add("agg col", s.secs() * 1e3, "ms");
    let s = measure(1, 5, || x.crossprod(&x).unwrap());
    t.add("gramian (8x8)", s.secs() * 1e3, "ms");

    // XLA vs native kmeans step, when artifacts exist
    if std::path::Path::new("artifacts/manifest.json").exists() {
        for (label, xla) in [("xla", true), ("native", false)] {
            let eng = Engine::new(EngineConfig {
                xla_dispatch: xla,
                ..Default::default()
            })
            .unwrap();
            let (x, _) = datasets::mix_gaussian(&eng, 131_072, 32, 10, 6.0, 42, None).unwrap();
            eng.ssd.drain_bursts();
            let s = measure(1, 3, || {
                flashmatrix::algs::kmeans(&x, 10, 1, 1).unwrap()
            });
            t.add(format!("kmeans step 131072x32 {label}"), s.secs() * 1e3, "ms");
        }
    }

    t.print();

    let mut report = BenchReport::new("genops_micro");
    report.add_table(&t);
    report.write(std::path::Path::new(&json_dir)).expect("bench json");
}
