//! Bench: cross-pass optimizer ablation — the iterative workloads whose
//! per-iteration batches the planner ([`flashmatrix::plan`]) fuses:
//! 10-iteration IRLS (three sinks per Newton step) and 10-iteration
//! PageRank (new-rank target + L1-change sink per power step), external
//! memory with a partition cache far smaller than the dataset plus the
//! deterministic SSD throttle, `cross_pass_opt` off vs on.
//!
//! Acceptance (gated by CI): with the optimizer on, each workload runs
//! STRICTLY fewer passes and reads STRICTLY fewer bytes from the store
//! per run, and its results are **bit-identical** to the opt-off run —
//! the planner only drops whole redundant evaluations, never a fold
//! order. Single-threaded so the bit-exactness claim is unconditional.
//!
//! Run: `cargo bench --bench cross_pass -- [--json-dir DIR]`. Emits
//! `BENCH_cross_pass.json` for the CI gate.

use std::sync::Arc;
use std::time::Instant;

use flashmatrix::algs;
use flashmatrix::config::{EngineConfig, StorageKind, ThrottleConfig};
use flashmatrix::datasets;
use flashmatrix::fmr::Engine;
use flashmatrix::harness::BenchReport;
use flashmatrix::metrics::MetricsSnapshot;
use flashmatrix::util::bench::{bench_args, Table};

const SSD_BPS: u64 = 512 << 20;
const ITERS: usize = 10;

fn engine(dir: &std::path::Path, cache_bytes: usize, opt: bool) -> Arc<Engine> {
    Engine::new(EngineConfig {
        storage: StorageKind::External,
        data_dir: dir.to_path_buf(),
        em_cache_bytes: cache_bytes,
        prefetch_depth: 2,
        throttle: Some(ThrottleConfig {
            read_bytes_per_sec: SSD_BPS,
            write_bytes_per_sec: SSD_BPS,
        }),
        threads: 1, // bit-exact folds across the ablation
        xla_dispatch: false,
        cross_pass_opt: opt,
        ..EngineConfig::default()
    })
    .expect("engine")
}

/// Cold-start the measured region: the dataset is already on the store,
/// so drop its write-through cache copies, drain the simulated SSD and
/// zero the counters — the run measures the iterations, not the build.
fn cold_start(eng: &Arc<Engine>) {
    if let Some(c) = &eng.cache {
        c.clear();
    }
    eng.ssd.drain_bursts();
    eng.metrics.reset();
}

fn irls(eng: &Arc<Engine>) -> (Vec<f64>, MetricsSnapshot, f64) {
    // 6 columns keeps io partitions at 3 MiB so the 4 MiB cache holds one
    let x = datasets::uniform(eng, 200_000, 6, -1.0, 1.0, 21, None).expect("x");
    let y = datasets::logistic_labels(&x, &[1.0, -0.5, 0.25, -1.5, 0.75, 0.0], 22).expect("y");
    cold_start(eng);
    let t0 = Instant::now();
    let fit = algs::logistic(&x, &y, ITERS, 1e-8).expect("irls");
    let secs = t0.elapsed().as_secs_f64();
    let mut fp = fit.beta.clone();
    fp.extend(fit.deviances);
    (fp, eng.metrics.snapshot(), secs)
}

fn pagerank(eng: &Arc<Engine>) -> (Vec<f64>, MetricsSnapshot, f64) {
    let (g, dangling) = datasets::pagerank_graph(eng, 1 << 15, 8, 99, None).expect("graph");
    cold_start(eng);
    let t0 = Instant::now();
    let pr = algs::pagerank(&g, &dangling, 0.85, ITERS, 0.0).expect("pagerank");
    let secs = t0.elapsed().as_secs_f64();
    let mut fp = pr.ranks.clone();
    fp.extend(pr.deltas);
    (fp, eng.metrics.snapshot(), secs)
}

fn main() {
    let args = bench_args();
    let json_dir = args.get_or("json-dir", ".").to_string();

    let mut t = Table::new(format!(
        "Cross-pass optimizer ablation: {ITERS}-iteration IRLS (200000x6) + \
         PageRank (32768 nodes), FM-EM small cache, SSD {} MiB/s",
        SSD_BPS >> 20
    ));
    let mut report = BenchReport::new("cross_pass");
    let mut ok = true;

    let cases: [(&str, usize, fn(&Arc<Engine>) -> (Vec<f64>, MetricsSnapshot, f64)); 2] =
        [("irls", 4 << 20, irls), ("pagerank", 64 << 10, pagerank)];
    for (name, cache_bytes, workload) in cases {
        let mut legs = Vec::new();
        for opt in [false, true] {
            let dir = std::env::temp_dir().join(format!(
                "fm-cross-pass-{name}-{}-{}",
                if opt { "on" } else { "off" },
                std::process::id()
            ));
            std::fs::create_dir_all(&dir).expect("bench data dir");
            let eng = engine(&dir, cache_bytes, opt);
            let (fp, m, secs) = workload(&eng);
            t.add_with(
                format!("{name} opt-{}", if opt { "on" } else { "off" }),
                secs,
                "s",
                vec![
                    ("passes".into(), m.passes_run as f64),
                    ("read_gb".into(), m.io_read_bytes as f64 / 1e9),
                    ("cse_hits".into(), m.opt_cse_hits as f64),
                    ("mat_decisions".into(), m.opt_mat_decisions as f64),
                    ("sinks_pruned".into(), m.opt_sinks_pruned as f64),
                ],
            );
            legs.push((fp, m));
            let _ = std::fs::remove_dir_all(&dir);
        }
        let (off_fp, off_m) = &legs[0];
        let (on_fp, on_m) = &legs[1];
        let fewer = on_m.passes_run < off_m.passes_run;
        let less_io = on_m.io_read_bytes < off_m.io_read_bytes;
        let identical = on_fp.len() == off_fp.len()
            && on_fp
                .iter()
                .zip(off_fp)
                .all(|(a, b)| a.to_bits() == b.to_bits());
        println!(
            "{name}: passes {} -> {} ({}), read {} -> {} B ({}), results {}",
            off_m.passes_run,
            on_m.passes_run,
            if fewer { "PASS" } else { "FAIL" },
            off_m.io_read_bytes,
            on_m.io_read_bytes,
            if less_io { "PASS" } else { "FAIL" },
            if identical {
                "PASS: bit-identical"
            } else {
                "FAIL: diverged"
            }
        );
        report.add_check(format!("fewer-passes: {name}"), fewer);
        report.add_check(format!("less-read-io: {name}"), less_io);
        report.add_check(format!("bit-identical: {name}"), identical);
        ok &= fewer && less_io && identical;
    }
    t.print();
    report.add_table(&t);
    report
        .write(std::path::Path::new(&json_dir))
        .expect("bench json");
    assert!(
        ok,
        "cross-pass optimizer must cut passes and read I/O without changing a bit"
    );
}
