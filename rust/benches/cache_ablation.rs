//! Bench: §III-B3 matrix-cache ablation — cache-on EM vs cache-off EM vs
//! in-memory, on a repeated-access (multi-iteration) workload whose total
//! external-memory footprint exceeds the cache.
//!
//! Layout: a larger-than-cache "cold" matrix (64 MiB) streams through
//! once, then a "hot" matrix (16 MiB, fits the 32 MiB cache) is scanned
//! `iters` times — the iterative access pattern of the paper's EM
//! algorithms. With the cache on, write-through population plus post-miss
//! refill serve the hot passes from memory; with it off every pass pays
//! simulated SSD bandwidth again. EM runs use one worker so the prefetch
//! thread's read-ahead (partition N+1 in flight while N computes) is also
//! exercised.
//!
//! Run: `cargo bench --bench cache_ablation -- [--iters N] [--json-dir DIR]`
//! (`--iters` overrides the hot-pass count, default 8).
//! Hit/miss/eviction/prefetch counts come from the engine's `metrics.rs`;
//! the run also emits `BENCH_cache_ablation.json` for the CI gate.

use std::sync::Arc;
use std::time::Instant;

use flashmatrix::config::{EngineConfig, StorageKind, ThrottleConfig};
use flashmatrix::datasets;
use flashmatrix::fmr::Engine;
use flashmatrix::harness::BenchReport;
use flashmatrix::util::bench::{bench_args, Table};

/// Simulated SSD bandwidth: slow enough that cache hits matter, fast
/// enough that the bench finishes in seconds.
const SSD_BPS: u64 = 256 << 20;
/// Cache sized between the hot matrix (16 MiB) and the total (80 MiB).
const CACHE_BYTES: usize = 32 << 20;
const HOT_ROWS: u64 = 1 << 18; //  x  8 cols x 8 B = 16 MiB
const COLD_ROWS: u64 = 1 << 19; // x 16 cols x 8 B = 64 MiB

fn engine(label: &str, dir: &std::path::Path, cache_bytes: usize, external: bool) -> Arc<Engine> {
    Engine::new(EngineConfig {
        storage: if external {
            StorageKind::External
        } else {
            StorageKind::InMem
        },
        data_dir: dir.join(label.replace(' ', "-")),
        em_cache_bytes: cache_bytes,
        prefetch_depth: if cache_bytes > 0 { 2 } else { 0 },
        throttle: if external {
            Some(ThrottleConfig {
                read_bytes_per_sec: SSD_BPS,
                write_bytes_per_sec: SSD_BPS,
            })
        } else {
            None
        },
        threads: 1, // single-worker EM scan: the §III-B3 overlap case
        xla_dispatch: false,
        ..EngineConfig::default()
    })
    .expect("engine")
}

/// One configuration's workload; returns timed seconds (generation and
/// its throttled writes are excluded from the timed region).
fn run(eng: &Arc<Engine>, iters: usize) -> f64 {
    let cold = datasets::uniform(eng, COLD_ROWS, 16, -1.0, 1.0, 3, None).expect("cold");
    let hot = datasets::uniform(eng, HOT_ROWS, 8, -1.0, 1.0, 5, None).expect("hot");
    // drain the buckets' standing burst: timed passes pay the full rate
    eng.ssd.drain_bursts();
    let t0 = Instant::now();
    let mut acc = cold.sum().expect("cold pass"); // streams past the cache
    for _ in 0..iters {
        acc += hot.sq().expect("sq").sum().expect("hot pass");
    }
    std::hint::black_box(acc);
    t0.elapsed().as_secs_f64()
}

fn main() {
    let args = bench_args();
    let iters = args.usize_or("iters", 8);
    let json_dir = args.get_or("json-dir", ".").to_string();
    let dir = std::env::temp_dir().join(format!("fm-cache-ablation-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench data dir");

    let mut t = Table::new(format!(
        "§III-B3 cache ablation: {iters} hot passes (16 MiB) + 1 cold pass (64 MiB), \
         cache {} MiB, SSD {} MiB/s",
        CACHE_BYTES >> 20,
        SSD_BPS >> 20
    ));
    let mut cache_on_secs = 0.0;
    let mut cache_off_secs = 0.0;
    for (label, cache_bytes, external) in [
        ("cache-on EM", CACHE_BYTES, true),
        ("cache-off EM", 0usize, true),
        ("in-mem", 0usize, false),
    ] {
        let eng = engine(label, &dir, cache_bytes, external);
        eng.metrics.reset();
        let secs = run(&eng, iters);
        let m = eng.metrics.snapshot();
        match label {
            "cache-on EM" => cache_on_secs = secs,
            "cache-off EM" => cache_off_secs = secs,
            _ => {}
        }
        t.add_with(
            label,
            secs,
            "s",
            vec![
                ("hits".into(), m.cache_hits as f64),
                ("misses".into(), m.cache_misses as f64),
                ("evictions".into(), m.cache_evictions as f64),
                ("prefetches".into(), m.prefetch_issued as f64),
                ("read_gb".into(), m.io_read_bytes as f64 / 1e9),
            ],
        );
    }
    t.print();

    let cache_wins = cache_on_secs < cache_off_secs;
    println!(
        "\ncache-on vs cache-off: {:.2}x — {}",
        cache_off_secs / cache_on_secs,
        if cache_wins {
            "PASS: write-through cache wins on repeated access"
        } else {
            "FAIL: cache-on did not beat cache-off"
        }
    );

    let mut report = BenchReport::new("cache_ablation");
    report.add_table(&t);
    report.add_check("cache-on-beats-cache-off", cache_wins);
    report.write(std::path::Path::new(&json_dir)).expect("bench json");

    let _ = std::fs::remove_dir_all(&dir);
}
