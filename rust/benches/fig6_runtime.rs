//! Bench: Fig 6(a) runtime + Fig 6(b) memory — FM-IM vs FM-EM vs the
//! MLlib-like baseline across all five algorithms.
//!
//! `cargo bench --bench fig6_runtime` (env FM_BENCH_N overrides rows).

use flashmatrix::harness::{self, Scale};

fn main() {
    let mut s = Scale::default();
    if let Ok(n) = std::env::var("FM_BENCH_N") {
        s.n = n.parse().unwrap_or(s.n);
    }
    let t = harness::fig6a(&s).expect("fig6a");
    t.print();
    let t = harness::fig6b(&s).expect("fig6b");
    t.print();
}
