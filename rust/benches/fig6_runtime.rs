//! Bench: Fig 6(a) runtime + Fig 6(b) memory — FM-IM vs FM-EM vs the
//! MLlib-like baseline across all five algorithms.
//!
//! `cargo bench --bench fig6_runtime -- [--n N] [--json-dir DIR]`
//! (`--n` overrides rows). Emits `BENCH_fig6_runtime.json`.

use flashmatrix::harness::{self, BenchReport, Scale};
use flashmatrix::util::bench::bench_args;

fn main() {
    let args = bench_args();
    let mut s = Scale::default();
    s.n = args.u64_or("n", s.n);
    let json_dir = args.get_or("json-dir", ".").to_string();

    let mut report = BenchReport::new("fig6_runtime");
    let t = harness::fig6a(&s).expect("fig6a");
    t.print();
    report.add_table(&t);
    let t = harness::fig6b(&s).expect("fig6b");
    t.print();
    report.add_table(&t);
    report.write(std::path::Path::new(&json_dir)).expect("bench json");
}
